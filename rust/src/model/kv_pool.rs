//! Block-paged KV storage with refcounted cross-request page sharing.
//!
//! The dense [`KvCache`] gives every serving slot a private
//! `(capacity, d_model)` K and V buffer per layer — at production batch
//! sizes those buffers, not the ~2-bit weights, dominate resident bytes, and
//! every request re-prefills shared system prompts from scratch. This module
//! is the PagedAttention-style answer (DESIGN.md §13): K/V rows live in
//! fixed-size [`KvPage`]s handed out by a shared [`KvPool`], a
//! [`PagedKvCache`] owns a *chain* of `Arc<KvPage>`s instead of one dense
//! buffer, and immutable prefix pages can be attached to many chains at once
//! so a hot prefix's prefill is paid once per server
//! (see [`crate::coordinator::PrefixCache`]).
//!
//! ## Page layout
//!
//! A [`KvPage`] holds one `(page_size, d_model)` K matrix and one V matrix
//! per layer. Chain position `pos` maps to page `pos / page_size`, row
//! `pos % page_size`. Rows are valid only below the owning cache's `len()`
//! — exactly the dense cache's fill-level rule, per page.
//!
//! ## Sharing and copy-on-write
//!
//! Pages are shared by cloning their `Arc`: the prefix trie publishes a
//! chain's full prompt pages, later admissions attach them read-only.
//! [`PagedKvCache::write_kv_at`] writes through `Arc::get_mut`; if the page
//! is shared (refcount > 1) the cache first copies the committed rows into a
//! fresh page and swaps it in — copy-on-write on the first divergent write.
//! In the serving loop writes only ever target positions past the attached
//! (full, immutable) prefix pages, so COW never fires there; it exists as
//! the safety rule that makes sharing unconditionally sound.
//!
//! ## Free-list reuse and determinism
//!
//! Released page buffers (request reset, slide+rebuild eviction) go to the
//! *owning cache's* local free list, never to shared pool state — every
//! allocate/reuse decision depends only on per-slot history, so the pool
//! counters are bit-identical at every `PALLAS_THREADS` setting (the §12
//! determinism contract extends to paged serving). The pool itself holds
//! only geometry and atomic telemetry counters. Pages dropped from the
//! prefix trie return to the allocator (counted in
//! [`KvPoolCounters::dropped`]) — trie eviction runs on the coordinator
//! thread only.
//!
//! ## Eviction
//!
//! [`PagedKvCache::begin_evict`] keeps the slide+rebuild contract of the
//! dense cache bit-for-bit: drop the oldest `evict_stride` tokens, release
//! the *entire* chain (owned buffers recycle through the local free list,
//! shared ones just drop their ref), and let the caller re-feed the
//! surviving window at its shifted absolute positions.
//!
//! The [`KvStore`] trait abstracts over [`KvCache`] and [`PagedKvCache`] so
//! [`crate::model::HostForward::decode_step`] / `prefill` / `prefill_block`
//! / `advance_block` and [`crate::coordinator::Server::serve_continuous`]
//! run unchanged on either layout; attention reads go through
//! [`KvLayerView`], which walks the page chain in the paged case.
//!
//! ## Quantized pages
//!
//! A pool built with [`KvPool::with_codec`] stores polar-decoupled codes
//! (DESIGN.md §15): every committed row's payload is the packed
//! direction×magnitude code words ([`crate::quant::kv::KvQuantCodec`]), and
//! the page's f32 matrices become the **decoded tile** — derived state that
//! [`PagedKvCache::write_kv_at`] refills through the codec's [`DecodeLut`]
//! the moment the codes land, so attention reads stay borrowed `&[f32]`
//! slices and [`KvLayerView`] is layout-blind. [`PageCodec`] names the
//! layout; COW copies code words alongside the tile, sharing/refcount/
//! eviction semantics are untouched, and [`KvPool::page_bits`] counts only
//! the code words (the tile is re-buildable bit-identically from the codes,
//! like the weight kernel's LUTs).
//!
//! [`DecodeLut`]: crate::quant::DecodeLut

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::quant::kv::KvQuantCodec;
use crate::tensor::Matrix;

use super::{GptConfig, KvCache};

/// The storage layout of a pool's pages: exact f32 rows, or packed
/// polar-decoupled codes plus a decoded f32 tile (DESIGN.md §15).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageCodec {
    /// Rows are stored exactly — the parity oracle (`--kv-quant 0`).
    F32,
    /// Rows are `dir_bits + mag_bits`-bit joint codes per 2-dim subvector;
    /// the page's f32 matrices hold the LUT-decoded tile.
    PcdVq { dir_bits: u32, mag_bits: u32 },
}

/// One fixed-size block of K/V rows: per layer, a `(page_size, d_model)` K
/// matrix and a V matrix. Rows are valid only below the owning cache's
/// `len()`; shared (prefix) pages are always completely full.
#[derive(Debug)]
pub struct KvPage {
    /// Per layer: `(page_size, d_model)` keys (the decoded tile when the
    /// pool carries a codec — derived state, zero payload bits).
    k: Vec<Matrix>,
    /// Per layer: `(page_size, d_model)` values (ditto).
    v: Vec<Matrix>,
    /// Per layer: `page_size · words_per_row` packed K code words
    /// (empty under [`PageCodec::F32`]).
    ck: Vec<Vec<u64>>,
    /// Per layer: packed V code words.
    cv: Vec<Vec<u64>>,
    /// `u64` words per packed code row (0 under [`PageCodec::F32`]).
    words_per_row: usize,
}

impl KvPage {
    fn new(n_layer: usize, page_size: usize, d_model: usize, words_per_row: usize) -> Self {
        KvPage {
            k: (0..n_layer).map(|_| Matrix::zeros(page_size, d_model)).collect(),
            v: (0..n_layer).map(|_| Matrix::zeros(page_size, d_model)).collect(),
            ck: (0..n_layer).map(|_| vec![0u64; page_size * words_per_row]).collect(),
            cv: (0..n_layer).map(|_| vec![0u64; page_size * words_per_row]).collect(),
            words_per_row,
        }
    }

    /// K row at in-page offset `off` for `layer`.
    #[inline]
    pub fn k_row(&self, layer: usize, off: usize) -> &[f32] {
        self.k[layer].row(off)
    }

    /// V row at in-page offset `off` for `layer`.
    #[inline]
    pub fn v_row(&self, layer: usize, off: usize) -> &[f32] {
        self.v[layer].row(off)
    }

    /// Packed K code words at in-page offset `off` (empty under
    /// [`PageCodec::F32`]) — the row's actual resident payload; the f32 row
    /// re-decodes from exactly these words.
    #[inline]
    pub fn k_codes(&self, layer: usize, off: usize) -> &[u64] {
        let w = self.words_per_row;
        &self.ck[layer][off * w..(off + 1) * w]
    }

    /// Packed V code words at in-page offset `off` (empty under
    /// [`PageCodec::F32`]).
    #[inline]
    pub fn v_codes(&self, layer: usize, off: usize) -> &[u64] {
        let w = self.words_per_row;
        &self.cv[layer][off * w..(off + 1) * w]
    }
}

/// Snapshot of the pool's telemetry counters. All five totals are
/// deterministic for a given request stream at every thread count — see the
/// module docs for why.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolCounters {
    /// Fresh page buffers created (never shrinks; `allocated · page_bits` is
    /// the pool's resident-byte high-water mark).
    pub allocated: u64,
    /// Acquisitions served from a cache-local free list instead of a fresh
    /// allocation.
    pub reused: u64,
    /// Page buffers returned to a local free list (reset / eviction churn).
    pub released: u64,
    /// Page buffers freed back to the allocator (prefix-trie eviction of a
    /// page no chain holds).
    pub dropped: u64,
    /// Copy-on-write page copies (a write hit a shared page).
    pub cow_copies: u64,
}

#[derive(Debug)]
struct PoolInner {
    /// First absolute layer index this pool's pages hold rows for (0 for a
    /// full-model pool; a shard node's pool covers only its layer range,
    /// DESIGN.md §16).
    layer_base: usize,
    /// Number of owned layers (`cfg.n_layer` for a full-model pool).
    n_layer: usize,
    d_model: usize,
    page_size: usize,
    /// Present iff pages store polar-decoupled codes. Shared by every cache
    /// drawing from this pool, so prefix pages published by one request
    /// decode identically for every attachment.
    codec: Option<Arc<KvQuantCodec>>,
    allocated: AtomicU64,
    reused: AtomicU64,
    released: AtomicU64,
    dropped: AtomicU64,
    cow_copies: AtomicU64,
}

/// Shared page allocator: geometry plus atomic telemetry. Cheap to clone
/// (an `Arc` handle); every [`PagedKvCache`] and the prefix trie hold one.
///
/// The pool deliberately has **no** shared free list — released buffers
/// recycle through the releasing cache's local list so that allocation
/// decisions never depend on cross-slot timing (DESIGN.md §12/§13).
#[derive(Clone, Debug)]
pub struct KvPool {
    inner: Arc<PoolInner>,
}

impl KvPool {
    /// Pool for `cfg`'s geometry with the given page size. Errors unless
    /// `1 <= page_size <= cfg.ctx` — a zero page can hold nothing and a page
    /// beyond the context window could never fill (and so never be shared).
    pub fn new(cfg: &GptConfig, page_size: usize) -> Result<Self> {
        Self::with_codec(cfg, page_size, None)
    }

    /// Pool whose pages store polar-decoupled codes quantized by `codec`
    /// (DESIGN.md §15); `None` is the exact [`PageCodec::F32`] layout.
    pub fn with_codec(
        cfg: &GptConfig,
        page_size: usize,
        codec: Option<Arc<KvQuantCodec>>,
    ) -> Result<Self> {
        Self::for_layers(cfg, page_size, codec, 0..cfg.n_layer)
    }

    /// Pool whose pages hold rows for only the layers in `layers` — the
    /// shard-node form (DESIGN.md §16): each node draws pages sized to its
    /// own layer range, while the layer arguments of the write/read paths
    /// stay *absolute* model indices. The codec (when present) keeps
    /// full-model geometry and absolute indexing, so per-node frozen
    /// codebooks are bit-identical to the single-node ones.
    pub(crate) fn for_layers(
        cfg: &GptConfig,
        page_size: usize,
        codec: Option<Arc<KvQuantCodec>>,
        layers: std::ops::Range<usize>,
    ) -> Result<Self> {
        anyhow::ensure!(
            (1..=cfg.ctx).contains(&page_size),
            "kv page size {page_size} out of range 1..={} (model ctx)",
            cfg.ctx
        );
        anyhow::ensure!(
            layers.start <= layers.end && layers.end <= cfg.n_layer,
            "kv pool layer range {layers:?} out of model range 0..{}",
            cfg.n_layer
        );
        if let Some(c) = &codec {
            anyhow::ensure!(
                c.n_layer() == cfg.n_layer && c.d_model() == cfg.d_model,
                "kv codec geometry ({} layers × {}) does not match model ({} × {})",
                c.n_layer(),
                c.d_model(),
                cfg.n_layer,
                cfg.d_model
            );
        }
        Ok(KvPool {
            inner: Arc::new(PoolInner {
                layer_base: layers.start,
                n_layer: layers.len(),
                d_model: cfg.d_model,
                page_size,
                codec,
                allocated: AtomicU64::new(0),
                reused: AtomicU64::new(0),
                released: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                cow_copies: AtomicU64::new(0),
            }),
        })
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.inner.page_size
    }

    /// The absolute layer range this pool's pages cover (`0..cfg.n_layer`
    /// for the full-model constructors).
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.inner.layer_base..self.inner.layer_base + self.inner.n_layer
    }

    /// Map an absolute model layer index onto the pages' local arrays.
    #[inline]
    fn local(&self, layer: usize) -> usize {
        debug_assert!(
            layer >= self.inner.layer_base && layer < self.inner.layer_base + self.inner.n_layer,
            "layer {layer} outside pool range {:?}",
            self.layers()
        );
        layer - self.inner.layer_base
    }

    /// The shared cache codec, when pages store codes.
    pub fn codec(&self) -> Option<&Arc<KvQuantCodec>> {
        self.inner.codec.as_ref()
    }

    /// The storage layout of this pool's pages.
    pub fn page_codec(&self) -> PageCodec {
        match &self.inner.codec {
            None => PageCodec::F32,
            Some(c) => PageCodec::PcdVq {
                dir_bits: c.spec().dir_bits(),
                mag_bits: c.spec().mag_bits(),
            },
        }
    }

    /// Resident payload bits of one page (both K and V, all layers): the
    /// f32 rows under [`PageCodec::F32`], the allocated word-aligned code
    /// words under [`PageCodec::PcdVq`] (the decoded tile is derived state
    /// and contributes nothing; the shared codebooks are counted once, at
    /// the codec — [`KvQuantCodec::codebook_bits`]).
    pub fn page_bits(&self) -> u64 {
        let rows = 2 * (self.inner.n_layer * self.inner.page_size) as u64;
        match &self.inner.codec {
            None => rows * self.inner.d_model as u64 * 32,
            Some(c) => rows * c.code_bits_per_row(),
        }
    }

    /// Fresh page buffers ever created; `pages_created() · page_bits()` is
    /// the pool-wide resident high-water mark.
    pub fn pages_created(&self) -> u64 {
        self.inner.allocated.load(Ordering::Relaxed)
    }

    /// Snapshot of all telemetry counters.
    pub fn counters(&self) -> KvPoolCounters {
        KvPoolCounters {
            allocated: self.inner.allocated.load(Ordering::Relaxed),
            reused: self.inner.reused.load(Ordering::Relaxed),
            released: self.inner.released.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
            cow_copies: self.inner.cow_copies.load(Ordering::Relaxed),
        }
    }

    /// True when this is a *full-model* pool whose pages can hold `cfg`'s
    /// K/V rows (a shard node's layer-range pool never matches — its caches
    /// must only be fed by the owning node).
    pub fn matches(&self, cfg: &GptConfig) -> bool {
        self.inner.layer_base == 0
            && self.inner.n_layer == cfg.n_layer
            && self.inner.d_model == cfg.d_model
    }

    /// True when this pool's layer range fits inside `cfg` — the weaker
    /// check node-range caches construct under.
    pub(crate) fn fits(&self, cfg: &GptConfig) -> bool {
        self.inner.layer_base + self.inner.n_layer <= cfg.n_layer
            && self.inner.d_model == cfg.d_model
    }

    /// A writable page buffer: recycled from `local` when possible, freshly
    /// allocated otherwise.
    fn take_buffer(&self, local: &mut Vec<KvPage>) -> KvPage {
        if let Some(page) = local.pop() {
            self.inner.reused.fetch_add(1, Ordering::Relaxed);
            page
        } else {
            self.inner.allocated.fetch_add(1, Ordering::Relaxed);
            let words = self.inner.codec.as_ref().map_or(0, |c| c.words_per_row());
            KvPage::new(self.inner.n_layer, self.inner.page_size, self.inner.d_model, words)
        }
    }

    /// Release one chain ref. If this was the last ref the buffer recycles
    /// into `local`; a still-shared page just drops the ref (the remaining
    /// holder — always including the prefix trie — will release it later).
    fn give_back(&self, page: Arc<KvPage>, local: &mut Vec<KvPage>) {
        if let Ok(buffer) = Arc::try_unwrap(page) {
            self.inner.released.fetch_add(1, Ordering::Relaxed);
            local.push(buffer);
        }
    }

    /// Drop a ref with no local list to recycle into (prefix-trie eviction).
    /// A last-ref drop frees the buffer to the allocator.
    pub(crate) fn drop_external(&self, page: Arc<KvPage>) {
        if Arc::try_unwrap(page).is_ok() {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn count_cow(&self) {
        self.inner.cow_copies.fetch_add(1, Ordering::Relaxed);
    }
}

/// Paged counterpart of [`KvCache`]: the same observable state machine
/// (token window, capacity, slide+rebuild eviction, telemetry) over a chain
/// of pool pages instead of one dense buffer. Byte-identical K/V rows and
/// token windows to the dense cache for any feed sequence — the paged-vs-
/// dense parity suites pin this.
#[derive(Debug)]
pub struct PagedKvCache {
    pool: KvPool,
    capacity: usize,
    evict_stride: usize,
    /// The token window the cached rows were computed from (`len()` entries).
    tokens: Vec<i32>,
    /// Page chain: position `p` lives in `pages[p / page_size]`.
    pages: Vec<Arc<KvPage>>,
    /// Buffers this cache released and may reuse (never shared).
    local_free: Vec<KvPage>,
    /// Tokens ever fed through the model into this cache (attach does NOT
    /// count — attached rows were computed by another request).
    total_fed: u64,
    evictions: u64,
    /// Tokens ever attached from shared prefix pages (telemetry).
    attached_tokens: u64,
}

impl PagedKvCache {
    /// Cache over `cfg.ctx` positions with the default `capacity/4` eviction
    /// stride, drawing pages from `pool`.
    pub fn new(cfg: &GptConfig, pool: &KvPool) -> Self {
        Self::with_stride(cfg, pool, cfg.ctx, (cfg.ctx / 4).max(1))
    }

    /// Full control over window capacity and eviction stride, clamped
    /// exactly like [`KvCache::with_stride`].
    pub fn with_stride(cfg: &GptConfig, pool: &KvPool, capacity: usize, stride: usize) -> Self {
        debug_assert!(pool.fits(cfg), "pool geometry mismatch");
        let capacity = capacity.clamp(1, cfg.ctx);
        PagedKvCache {
            pool: pool.clone(),
            capacity,
            evict_stride: stride.clamp(1, capacity),
            tokens: Vec::with_capacity(capacity),
            pages: Vec::new(),
            local_free: Vec::new(),
            total_fed: 0,
            evictions: 0,
            attached_tokens: 0,
        }
    }

    /// Valid cached positions (= tokens in the current window).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Maximum window length before eviction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tokens dropped per window slide.
    pub fn evict_stride(&self) -> usize {
        self.evict_stride
    }

    /// Tokens per page (the pool's geometry).
    pub fn page_size(&self) -> usize {
        self.pool.page_size()
    }

    /// The token window the cached rows correspond to.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Tokens ever fed through the model (attach-shared tokens excluded —
    /// that exclusion is what lets tests assert "prefill work proportional
    /// to the cold suffix only").
    pub fn total_fed(&self) -> u64 {
        self.total_fed
    }

    /// Window slides performed so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Tokens ever attached from shared prefix pages.
    pub fn attached_tokens(&self) -> u64 {
        self.attached_tokens
    }

    /// The current page chain (prefix publication clones these `Arc`s).
    pub fn pages(&self) -> &[Arc<KvPage>] {
        &self.pages
    }

    /// Buffers parked on this cache's local free list.
    pub fn local_free_len(&self) -> usize {
        self.local_free.len()
    }

    /// f32 bits resident in this cache's chain + local free list. Shared
    /// pages are counted once per holder here; pool-wide residency is
    /// `KvPool::pages_created() · page_bits()`.
    pub fn memory_bits(&self) -> u64 {
        (self.pages.len() + self.local_free.len()) as u64 * self.pool.page_bits()
    }

    /// True when this cache's geometry matches `cfg`.
    pub fn compatible_with(&self, cfg: &GptConfig) -> bool {
        self.pool.matches(cfg) && self.capacity <= cfg.ctx
    }

    /// K row of (absolute) `layer` at chain position `pos` (`pos < len()`),
    /// for parity tests against the dense layout.
    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let ps = self.pool.page_size();
        self.pages[pos / ps].k_row(self.pool.local(layer), pos % ps)
    }

    /// V row of (absolute) `layer` at chain position `pos` (`pos < len()`).
    pub fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let ps = self.pool.page_size();
        self.pages[pos / ps].v_row(self.pool.local(layer), pos % ps)
    }

    /// Drop all cached state (new-request boundary): the page chain releases
    /// — owned buffers recycle into the local free list, shared refs drop —
    /// and the token window clears. Telemetry survives, like
    /// [`KvCache::reset`].
    pub fn reset(&mut self) {
        self.release_chain();
        self.tokens.clear();
    }

    /// Attach already-computed prefix pages to an empty cache: the chain
    /// takes shared refs on `chain` and the window starts at `tokens`
    /// without feeding anything through the model. `tokens` must exactly
    /// fill `chain` (whole pages only — a partial page could still be
    /// written by its owner).
    pub fn attach(&mut self, chain: &[Arc<KvPage>], tokens: &[i32]) {
        assert!(self.tokens.is_empty() && self.pages.is_empty(), "attach requires an empty cache");
        assert_eq!(
            tokens.len(),
            chain.len() * self.pool.page_size(),
            "attach must cover whole pages"
        );
        assert!(tokens.len() <= self.capacity, "attach past capacity");
        self.pages.extend(chain.iter().cloned());
        self.tokens.extend_from_slice(tokens);
        self.attached_tokens += tokens.len() as u64;
    }

    fn release_chain(&mut self) {
        let PagedKvCache { pool, pages, local_free, .. } = self;
        for page in pages.drain(..) {
            pool.give_back(page, local_free);
        }
    }

    /// Begin a window slide — same contract as [`KvCache::begin_evict`]:
    /// drop the oldest `evict_stride` tokens, invalidate every cached row
    /// (here: release the whole chain), return the survivors for re-feed.
    pub(crate) fn begin_evict(&mut self) -> Vec<i32> {
        let stride = self.evict_stride.min(self.tokens.len());
        let keep = self.tokens[stride..].to_vec();
        self.tokens.clear();
        self.release_chain();
        self.evictions += 1;
        keep
    }

    /// A mutable view of the page holding chain position `pos`, extending
    /// the chain and copying-on-write as needed.
    fn writable_page(&mut self, page_idx: usize) -> &mut KvPage {
        while self.pages.len() <= page_idx {
            let PagedKvCache { pool, local_free, .. } = self;
            let buffer = pool.take_buffer(local_free);
            self.pages.push(Arc::new(buffer));
        }
        if Arc::get_mut(&mut self.pages[page_idx]).is_none() {
            // Shared page: copy the committed rows, then swap in the copy.
            let ps = self.pool.page_size();
            let valid = self.tokens.len().saturating_sub(page_idx * ps).min(ps);
            let PagedKvCache { pool, local_free, .. } = self;
            let mut fresh = pool.take_buffer(local_free);
            let src = &self.pages[page_idx];
            let w = fresh.words_per_row;
            for layer in 0..fresh.k.len() {
                for row in 0..valid {
                    fresh.k[layer].row_mut(row).copy_from_slice(src.k[layer].row(row));
                    fresh.v[layer].row_mut(row).copy_from_slice(src.v[layer].row(row));
                }
                // code-carrying pages: the packed payload rides along so the
                // copy stays re-decodable (tile ≡ decode(codes) invariant)
                if w > 0 {
                    let n = valid * w;
                    fresh.ck[layer][..n].copy_from_slice(&src.ck[layer][..n]);
                    fresh.cv[layer][..n].copy_from_slice(&src.cv[layer][..n]);
                }
            }
            self.pool.count_cow();
            let shared = std::mem::replace(&mut self.pages[page_idx], Arc::new(fresh));
            // The old ref just drops: a shared page always has another
            // holder (the prefix trie), so it cannot be the last ref here.
            drop(shared);
        }
        Arc::get_mut(&mut self.pages[page_idx]).expect("exclusive after COW")
    }

    /// Write the K/V rows of one (still uncommitted) position for one
    /// layer. Under [`PageCodec::PcdVq`] the rows are quantized against the
    /// layer's codec (frozen on the layer's first-ever write) into packed
    /// code words, and the page's f32 matrices receive the LUT-decoded tile
    /// — so every later read sees `decode(encode(row))`, bit-identically
    /// reproducible from the codes alone.
    pub(crate) fn write_kv_at(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.capacity, "write_kv_at past capacity");
        let ps = self.pool.page_size();
        let (page_idx, off) = (pos / ps, pos % ps);
        // Page arrays are local to the pool's layer range; the codec is
        // indexed by the absolute layer (same grids as a full-model cache).
        let l = self.pool.local(layer);
        let codec = self.pool.codec().cloned();
        let page = self.writable_page(page_idx);
        match codec {
            None => {
                page.k[l].row_mut(off).copy_from_slice(k_row);
                page.v[l].row_mut(off).copy_from_slice(v_row);
            }
            Some(codec) => {
                let lc = codec.observe(layer, k_row, v_row);
                let w = codec.words_per_row();
                let kw = &mut page.ck[l][off * w..(off + 1) * w];
                codec.encode_row(lc, k_row, kw, page.k[l].row_mut(off));
                let vw = &mut page.cv[l][off * w..(off + 1) * w];
                codec.encode_row(lc, v_row, vw, page.v[l].row_mut(off));
            }
        }
    }

    /// Finish a block step — same contract as [`KvCache::commit_block`].
    pub(crate) fn commit_block(&mut self, tokens: &[i32]) {
        debug_assert!(
            self.tokens.len() + tokens.len() <= self.capacity,
            "commit_block past capacity"
        );
        self.tokens.extend_from_slice(tokens);
        self.total_fed += tokens.len() as u64;
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        // Refs held by a dying cache must not strand trie-shared pages in a
        // "someone still holds this" state.
        self.release_chain();
    }
}

/// Read view of one layer's K/V rows for the attention inner loop —
/// contiguous matrices for the dense cache, a page walk for the paged one.
/// `Sync` so [`crate::exec::Pool::scope_groups_mut`] strips can share it.
pub enum KvLayerView<'a> {
    Dense { k: &'a Matrix, v: &'a Matrix },
    /// `layer` here is *pool-local* (absolute minus the pool's first owned
    /// layer) — [`PagedKvCache::attn_view`] converts before constructing.
    Paged { pages: &'a [Arc<KvPage>], layer: usize, page_size: usize },
}

impl KvLayerView<'_> {
    /// K row at window position `pos`.
    #[inline]
    pub fn k_row(&self, pos: usize) -> &[f32] {
        match self {
            KvLayerView::Dense { k, .. } => k.row(pos),
            KvLayerView::Paged { pages, layer, page_size } => {
                pages[pos / *page_size].k_row(*layer, pos % *page_size)
            }
        }
    }

    /// V row at window position `pos`.
    #[inline]
    pub fn v_row(&self, pos: usize) -> &[f32] {
        match self {
            KvLayerView::Dense { v, .. } => v.row(pos),
            KvLayerView::Paged { pages, layer, page_size } => {
                pages[pos / *page_size].v_row(*layer, pos % *page_size)
            }
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for super::KvCache {}
    impl Sealed for super::PagedKvCache {}
}

/// The cache contract [`crate::model::HostForward`]'s incremental paths are
/// generic over: the dense [`KvCache`] and the paged [`PagedKvCache`]
/// implement identical observable semantics (window, slide+rebuild
/// eviction, block commit), so `decode_step`/`prefill`/`prefill_block`
/// produce byte-identical results on either. Sealed: the forward pass's
/// correctness argument only covers these two layouts.
pub trait KvStore: sealed::Sealed {
    /// Valid cached positions (= tokens in the current window).
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Maximum window length before eviction.
    fn capacity(&self) -> usize;
    /// The token window the cached rows correspond to.
    fn tokens(&self) -> &[i32];
    /// True when this cache's geometry matches `cfg`.
    fn compatible_with(&self, cfg: &GptConfig) -> bool;
    /// Drop all cached state at a request boundary (telemetry survives).
    fn reset(&mut self);
    #[doc(hidden)]
    fn begin_evict(&mut self) -> Vec<i32>;
    #[doc(hidden)]
    fn write_kv_at(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]);
    #[doc(hidden)]
    fn commit_block(&mut self, tokens: &[i32]);
    /// Read view of one layer's K/V rows for attention.
    fn attn_view(&self, layer: usize) -> KvLayerView<'_>;
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        KvCache::len(self)
    }
    fn capacity(&self) -> usize {
        KvCache::capacity(self)
    }
    fn tokens(&self) -> &[i32] {
        KvCache::tokens(self)
    }
    fn compatible_with(&self, cfg: &GptConfig) -> bool {
        KvCache::compatible_with(self, cfg)
    }
    fn reset(&mut self) {
        KvCache::reset(self)
    }
    fn begin_evict(&mut self) -> Vec<i32> {
        KvCache::begin_evict(self)
    }
    fn write_kv_at(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        KvCache::write_kv_at(self, layer, pos, k_row, v_row)
    }
    fn commit_block(&mut self, tokens: &[i32]) {
        KvCache::commit_block(self, tokens)
    }
    fn attn_view(&self, layer: usize) -> KvLayerView<'_> {
        let (k, v) = self.layer(layer);
        KvLayerView::Dense { k, v }
    }
}

impl KvStore for PagedKvCache {
    fn len(&self) -> usize {
        PagedKvCache::len(self)
    }
    fn capacity(&self) -> usize {
        PagedKvCache::capacity(self)
    }
    fn tokens(&self) -> &[i32] {
        PagedKvCache::tokens(self)
    }
    fn compatible_with(&self, cfg: &GptConfig) -> bool {
        PagedKvCache::compatible_with(self, cfg)
    }
    fn reset(&mut self) {
        PagedKvCache::reset(self)
    }
    fn begin_evict(&mut self) -> Vec<i32> {
        PagedKvCache::begin_evict(self)
    }
    fn write_kv_at(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        PagedKvCache::write_kv_at(self, layer, pos, k_row, v_row)
    }
    fn commit_block(&mut self, tokens: &[i32]) {
        PagedKvCache::commit_block(self, tokens)
    }
    fn attn_view(&self, layer: usize) -> KvLayerView<'_> {
        KvLayerView::Paged {
            pages: &self.pages,
            layer: self.pool.local(layer),
            page_size: self.pool.page_size(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GptConfig {
        GptConfig { vocab: 256, d_model: 32, n_layer: 3, n_head: 4, d_ff: 64, ctx: 16 }
    }

    fn fill(c: &mut PagedKvCache, toks: &[i32]) {
        let base = c.len();
        for (j, &t) in toks.iter().enumerate() {
            for l in 0..3 {
                let kr = vec![t as f32 + l as f32; 32];
                let vr = vec![-(t as f32) - l as f32; 32];
                c.write_kv_at(l, base + j, &kr, &vr);
            }
        }
        c.commit_block(toks);
    }

    #[test]
    fn pool_rejects_degenerate_page_sizes() {
        assert!(KvPool::new(&cfg(), 0).is_err());
        assert!(KvPool::new(&cfg(), 17).is_err());
        assert!(KvPool::new(&cfg(), 1).is_ok());
        assert!(KvPool::new(&cfg(), 16).is_ok());
    }

    #[test]
    fn geometry_mirrors_dense_cache() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let c = PagedKvCache::new(&cfg(), &pool);
        let d = KvCache::new(&cfg());
        assert_eq!(c.capacity(), d.capacity());
        assert_eq!(c.evict_stride(), d.evict_stride());
        assert!(c.compatible_with(&cfg()));
        assert_eq!(pool.page_bits(), 2 * 3 * 4 * 32 * 32);
        let other = GptConfig { d_model: 64, ..cfg() };
        assert!(!c.compatible_with(&other));
    }

    #[test]
    fn write_commit_reset_recycles_pages() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut c = PagedKvCache::new(&cfg(), &pool);
        fill(&mut c, &[5, 9, 2, 7, 1]); // spans two pages
        assert_eq!(c.len(), 5);
        assert_eq!(c.pages().len(), 2);
        assert_eq!(c.k_row(1, 4)[0], 1.0 + 1.0);
        assert_eq!(c.v_row(2, 0)[0], -5.0 - 2.0);
        assert_eq!(pool.counters().allocated, 2);
        c.reset();
        assert!(c.is_empty());
        assert_eq!(c.local_free_len(), 2, "owned pages recycle locally");
        assert_eq!(c.total_fed(), 5, "telemetry survives reset");
        fill(&mut c, &[3, 3, 3]);
        let counters = pool.counters();
        assert_eq!(counters.allocated, 2, "no fresh allocation after recycle");
        assert_eq!(counters.reused, 1);
        assert_eq!(counters.released, 2);
    }

    #[test]
    fn begin_evict_matches_dense_contract_and_releases_chain() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut c = PagedKvCache::with_stride(&cfg(), &pool, 8, 3);
        fill(&mut c, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(c.len(), c.capacity());
        let keep = c.begin_evict();
        assert_eq!(keep, vec![3, 4, 5, 6, 7]);
        assert!(c.is_empty());
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.pages().len(), 0, "chain fully released on slide");
        assert_eq!(c.local_free_len(), 2);
    }

    #[test]
    fn attach_shares_pages_and_skips_total_fed() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut owner = PagedKvCache::new(&cfg(), &pool);
        fill(&mut owner, &[10, 11, 12, 13, 14, 15]); // 1.5 pages
        let shared: Vec<_> = owner.pages()[..1].to_vec(); // the full page only
        let mut borrower = PagedKvCache::new(&cfg(), &pool);
        borrower.attach(&shared, &owner.tokens()[..4]);
        assert_eq!(borrower.len(), 4);
        assert_eq!(borrower.tokens(), &[10, 11, 12, 13]);
        assert_eq!(borrower.total_fed(), 0, "attached tokens were not fed");
        assert_eq!(borrower.attached_tokens(), 4);
        assert_eq!(borrower.k_row(0, 2), owner.k_row(0, 2), "rows are shared");
        // releasing the borrower must NOT recycle the still-shared page
        borrower.reset();
        assert_eq!(borrower.local_free_len(), 0);
        assert_eq!(owner.k_row(0, 2)[0], 12.0, "owner rows untouched");
    }

    #[test]
    fn write_into_shared_page_copies_on_write() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut owner = PagedKvCache::new(&cfg(), &pool);
        fill(&mut owner, &[1, 2, 3, 4]);
        let mut borrower = PagedKvCache::new(&cfg(), &pool);
        borrower.attach(&owner.pages().to_vec(), owner.tokens());
        // divergent write: borrower evicts down to 1 committed token, then
        // overwrites position 1 of the shared page
        let keep = borrower.begin_evict(); // stride 4 on capacity 16
        assert_eq!(keep.len(), 0);
        borrower.attach(&owner.pages().to_vec(), owner.tokens());
        borrower.tokens.truncate(1); // simulate a 1-token committed window
        borrower.write_kv_at(0, 1, &[99.0; 32], &[98.0; 32]);
        assert_eq!(pool.counters().cow_copies, 1);
        assert_eq!(borrower.k_row(0, 1)[0], 99.0);
        assert_eq!(owner.k_row(0, 1)[0], 2.0, "owner page untouched by COW");
        assert_eq!(borrower.k_row(0, 0), owner.k_row(0, 0), "committed row copied");
    }

    fn quant_pool(bits: u32) -> (Arc<KvQuantCodec>, KvPool) {
        use crate::quant::kv::KvQuantSpec;
        let cfg = cfg();
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(bits).unwrap(),
            cfg.n_layer,
            cfg.d_model,
            7,
        ));
        let pool = KvPool::with_codec(&cfg, 4, Some(codec.clone())).unwrap();
        (codec, pool)
    }

    fn probe_row(pos: usize, layer: usize, salt: usize) -> Vec<f32> {
        (0..32).map(|i| ((pos * 31 + i * 7 + layer * 13 + salt) % 17) as f32 - 8.0).collect()
    }

    #[test]
    fn quantized_pages_carry_redecodable_codes() {
        let (codec, pool) = quant_pool(4);
        assert_eq!(pool.page_codec(), PageCodec::PcdVq { dir_bits: 6, mag_bits: 2 });
        // payload accounting: word-aligned code words only, no tile bits
        assert_eq!(pool.page_bits(), 2 * 3 * 4 * codec.code_bits_per_row());
        assert!(pool.page_bits() < 2 * 3 * 4 * 32 * 32, "codes beat f32 rows");
        let mut c = PagedKvCache::new(&cfg(), &pool);
        for pos in 0..5 {
            for l in 0..3 {
                c.write_kv_at(l, pos, &probe_row(pos, l, 0), &probe_row(pos, l, 9));
            }
        }
        c.commit_block(&[1, 2, 3, 4, 5]);
        assert!(codec.frozen());
        // the resident f32 tile is derived state: re-decoding the packed
        // codes reproduces it bit-for-bit
        let ps = pool.page_size();
        let mut out = vec![0.0f32; 32];
        for pos in 0..5 {
            for l in 0..3 {
                let lc = codec.layer(l).unwrap();
                let page = &c.pages()[pos / ps];
                codec.decode_row(lc, page.k_codes(l, pos % ps), &mut out);
                let tile: Vec<u32> = c.k_row(l, pos).iter().map(|x| x.to_bits()).collect();
                let redo: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                assert_eq!(tile, redo, "layer {l} pos {pos}: tile is not decode(codes)");
                codec.decode_row(lc, page.v_codes(l, pos % ps), &mut out);
                let vtile: Vec<u32> = c.v_row(l, pos).iter().map(|x| x.to_bits()).collect();
                let vredo: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                assert_eq!(vtile, vredo);
            }
        }
    }

    #[test]
    fn f32_pages_have_no_code_payload() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        assert_eq!(pool.page_codec(), PageCodec::F32);
        let mut c = PagedKvCache::new(&cfg(), &pool);
        fill(&mut c, &[1, 2]);
        assert!(c.pages()[0].k_codes(0, 0).is_empty());
        assert!(c.pages()[0].v_codes(0, 1).is_empty());
    }

    #[test]
    fn cow_copies_code_words_alongside_tile() {
        let (codec, pool) = quant_pool(4);
        let mut owner = PagedKvCache::new(&cfg(), &pool);
        for pos in 0..4 {
            for l in 0..3 {
                owner.write_kv_at(l, pos, &probe_row(pos, l, 0), &probe_row(pos, l, 9));
            }
        }
        owner.commit_block(&[1, 2, 3, 4]);
        let mut borrower = PagedKvCache::new(&cfg(), &pool);
        borrower.attach(&owner.pages().to_vec(), owner.tokens());
        borrower.tokens.truncate(2);
        borrower.write_kv_at(0, 2, &probe_row(90, 0, 3), &probe_row(90, 0, 4));
        assert_eq!(pool.counters().cow_copies, 1);
        // committed rows 0..2: tile AND codes copied, still re-decodable
        let mut out = vec![0.0f32; 32];
        for pos in 0..2 {
            assert_eq!(borrower.k_row(0, pos), owner.k_row(0, pos));
            let page = &borrower.pages()[0];
            assert_eq!(page.k_codes(0, pos), owner.pages()[0].k_codes(0, pos));
            codec.decode_row(codec.layer(0).unwrap(), page.k_codes(0, pos), &mut out);
            assert_eq!(
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                borrower.k_row(0, pos).iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
        // the divergent row diverged on the borrower only
        assert_ne!(
            borrower.pages()[0].k_codes(0, 2),
            owner.pages()[0].k_codes(0, 2),
            "divergent write must not alias the shared payload"
        );
    }

    #[test]
    fn codec_geometry_mismatch_is_rejected() {
        use crate::quant::kv::KvQuantSpec;
        let other = GptConfig { d_model: 64, ..cfg() };
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(4).unwrap(),
            other.n_layer,
            other.d_model,
            7,
        ));
        assert!(KvPool::with_codec(&cfg(), 4, Some(codec)).is_err());
    }

    #[test]
    fn layer_view_walks_pages() {
        let pool = KvPool::new(&cfg(), 2).unwrap();
        let mut c = PagedKvCache::new(&cfg(), &pool);
        fill(&mut c, &[4, 5, 6]);
        let view = c.attn_view(1);
        assert_eq!(view.k_row(2)[0], 6.0 + 1.0);
        assert_eq!(view.v_row(0)[0], -4.0 - 1.0);
        let dense = KvCache::new(&cfg());
        match KvStore::attn_view(&dense, 0) {
            KvLayerView::Dense { .. } => {}
            KvLayerView::Paged { .. } => panic!("dense cache must yield a dense view"),
        }
    }
}
