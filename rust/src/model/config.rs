//! Model hyper-parameters — mirrors `python/compile/model.py::GptConfig`.

use anyhow::{bail, Result};

/// tinygpt hyper-parameters, read back from the `meta.*` entries of a weight
/// container (so Rust never hard-codes the zoo).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GptConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub ctx: usize,
}

impl GptConfig {
    /// Quantizable matrix names, in the fixed order shared with python
    /// (`model.quantizable_names`).
    pub fn quantizable_names(&self) -> Vec<String> {
        let mut names = Vec::with_capacity(self.n_layer * 6 + 1);
        for i in 0..self.n_layer {
            for suffix in ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.w1", "mlp.w2"] {
                names.push(format!("layer{i}.{suffix}"));
            }
        }
        names.push("head.w".to_string());
        names
    }

    /// (rows, cols) of a quantizable matrix; rows = input dim = RHT axis.
    pub fn weight_shape(&self, name: &str) -> Result<(usize, usize)> {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        if name.ends_with("mlp.w1") {
            Ok((d, f))
        } else if name.ends_with("mlp.w2") {
            Ok((f, d))
        } else if name == "head.w" {
            Ok((d, v))
        } else if name.contains("attn.") {
            Ok((d, d))
        } else {
            bail!("'{name}' is not a quantizable matrix")
        }
    }

    /// Per-head dimension of the attention split.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_head
    }

    /// f32 bits of K/V state one full-context [`crate::model::KvCache`]
    /// holds: `2 · n_layer · ctx · d_model · 32` (K and V, one row per
    /// position per layer). Heads factor out: `n_head · head_dim = d_model`.
    pub fn kv_cache_bits(&self) -> u64 {
        2 * (self.n_layer * self.ctx * self.d_model) as u64 * 32
    }

    /// Total quantizable parameter count.
    pub fn quantizable_params(&self) -> usize {
        self.quantizable_names()
            .iter()
            .map(|n| {
                let (r, c) = self.weight_shape(n).unwrap();
                r * c
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GptConfig {
        GptConfig { vocab: 256, d_model: 128, n_layer: 2, n_head: 4, d_ff: 512, ctx: 128 }
    }

    #[test]
    fn quantizable_names_order_matches_python() {
        let names = cfg().quantizable_names();
        assert_eq!(names.len(), 2 * 6 + 1);
        assert_eq!(names[0], "layer0.attn.wq");
        assert_eq!(names[5], "layer0.mlp.w2");
        assert_eq!(names[6], "layer1.attn.wq");
        assert_eq!(names.last().unwrap(), "head.w");
    }

    #[test]
    fn weight_shapes() {
        let c = cfg();
        assert_eq!(c.weight_shape("layer0.attn.wq").unwrap(), (128, 128));
        assert_eq!(c.weight_shape("layer1.mlp.w1").unwrap(), (128, 512));
        assert_eq!(c.weight_shape("layer1.mlp.w2").unwrap(), (512, 128));
        assert_eq!(c.weight_shape("head.w").unwrap(), (128, 256));
        assert!(c.weight_shape("embed.tok").is_err());
    }

    #[test]
    fn quantizable_param_count() {
        // per layer: 4*128*128 + 2*128*512 = 196608; head: 128*256
        assert_eq!(cfg().quantizable_params(), 2 * 196_608 + 32_768);
    }

    #[test]
    fn kv_cache_bits_formula() {
        let c = cfg();
        assert_eq!(c.head_dim(), 32);
        // K + V, 2 layers, 128 positions, 128 dims, f32
        assert_eq!(c.kv_cache_bits(), 2 * 2 * 128 * 128 * 32);
    }
}
