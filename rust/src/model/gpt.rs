//! Weight container + quantized-model representation.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::GptConfig;
use crate::io::Pct;
use crate::quant::{QuantizedWeight, Quantizer};
use crate::tensor::Matrix;

/// A loaded tinygpt: config + all named parameter tensors (f32).
#[derive(Clone)]
pub struct GptModel {
    pub config: GptConfig,
    /// All parameters, keyed by python-side names. 2-D tensors are stored
    /// with their natural (rows, cols); 1-D tensors as (len, 1).
    pub tensors: BTreeMap<String, Matrix>,
    /// Original dims per tensor (manifest feeding needs exact ranks).
    pub dims: BTreeMap<String, Vec<usize>>,
    pub name: String,
}

impl GptModel {
    /// Load a `.pct` weight container written by `train.py::save_model`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let pct = Pct::load(path)?;
        let meta = |key: &str| -> Result<usize> {
            Ok(pct.get(&format!("meta.{key}"))?.scalar_u64()? as usize)
        };
        let config = GptConfig {
            vocab: meta("vocab")?,
            d_model: meta("d_model")?,
            n_layer: meta("n_layer")?,
            n_head: meta("n_head")?,
            d_ff: meta("d_ff")?,
            ctx: meta("ctx")?,
        };
        let mut tensors = BTreeMap::new();
        let mut dims = BTreeMap::new();
        for name in pct.names().map(str::to_string).collect::<Vec<_>>() {
            if name.starts_with("meta.") {
                continue;
            }
            let e = pct.get(&name)?;
            let data = e.as_f32()?.to_vec();
            let (rows, cols) = match e.dims.len() {
                1 => (e.dims[0] as usize, 1),
                2 => (e.dims[0] as usize, e.dims[1] as usize),
                n => anyhow::bail!("tensor '{name}' has unsupported rank {n}"),
            };
            dims.insert(name.clone(), e.dims.iter().map(|&d| d as usize).collect());
            tensors.insert(name, Matrix::from_vec(data, rows, cols));
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(GptModel { config, tensors, dims, name })
    }

    pub fn tensor(&self, name: &str) -> Result<&Matrix> {
        self.tensors
            .get(name)
            .with_context(|| format!("model has no tensor '{name}'"))
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|m| m.len()).sum()
    }

    /// Apply a quantizer to every quantizable matrix, returning the
    /// fake-quant model (same tensor set, quantizable ones replaced) and the
    /// aggregate payload bits.
    pub fn fake_quantize(&self, quantizer: &dyn Quantizer) -> (GptModel, u64) {
        let mut out = self.clone();
        let mut payload = 0u64;
        for name in self.config.quantizable_names() {
            let w = &self.tensors[&name];
            let qw = quantizer.quantize(w);
            payload += qw.payload_bits();
            out.tensors.insert(name, qw.into_dequantized());
        }
        (out, payload)
    }

    /// All sample vectors (k-dim groups of every quantizable matrix) — the
    /// training pool for coupled-VQ baselines.
    pub fn quantizable_vectors(&self, k: usize) -> Matrix {
        let mut data = Vec::new();
        for name in self.config.quantizable_names() {
            data.extend_from_slice(self.tensors[&name].as_slice());
        }
        let n = data.len() / k;
        Matrix::from_vec(data[..n * k].to_vec(), n, k)
    }
}

/// A quantized model as a **compressed artifact collection**: per-matrix
/// packed-code payloads referencing shared codebooks, plus the fp tensors
/// (embeddings, norms) the paper leaves dense. This is the form the serving
/// stack keeps resident (codes + codebooks only) — dense weights exist only
/// where a caller explicitly materializes them ([`Self::to_dense`]).
#[derive(Clone)]
pub struct QuantizedGpt {
    pub config: GptConfig,
    pub name: String,
    /// Compressed quantizable weights, keyed by name.
    pub weights: BTreeMap<String, QuantizedWeight>,
    /// Unquantized tensors (embeddings, norms), as in the source model.
    pub fp_tensors: BTreeMap<String, Matrix>,
    pub fp_dims: BTreeMap<String, Vec<usize>>,
}

impl QuantizedGpt {
    /// Quantize a model with any [`Quantizer`], keeping the real compressed
    /// codes per layer.
    pub fn quantize<Q: Quantizer + ?Sized>(model: &GptModel, quantizer: &Q) -> Self {
        let mut weights = BTreeMap::new();
        for name in model.config.quantizable_names() {
            let qw = quantizer.quantize(&model.tensors[&name]);
            weights.insert(name, qw);
        }
        Self::from_artifacts(model, weights)
    }

    /// Assemble from per-layer artifacts + the source model's fp tensors —
    /// the single fp-split rule shared by [`Self::quantize`] and the
    /// layer-parallel scheduler.
    pub fn from_artifacts(
        model: &GptModel,
        weights: BTreeMap<String, QuantizedWeight>,
    ) -> Self {
        let mut fp_tensors = model.tensors.clone();
        let mut fp_dims = model.dims.clone();
        for name in weights.keys() {
            fp_tensors.remove(name);
            fp_dims.remove(name);
        }
        QuantizedGpt {
            config: model.config,
            name: model.name.clone(),
            weights,
            fp_tensors,
            fp_dims,
        }
    }

    /// Total payload bits of the compressed representation (codes + scales +
    /// seeds; codebooks amortize across the model per §A.3) — *measured*
    /// from the packed streams, not estimated.
    pub fn payload_bits(&self) -> u64 {
        self.weights.values().map(|w| w.payload_bits()).sum()
    }

    /// Bits of the distinct shared codebooks the artifacts reference
    /// (deduplicated by decoder spec — `Arc`-shared codebooks count once).
    pub fn codebook_bits(&self) -> u64 {
        crate::quant::dedup_codebook_bits(self.weights.values())
    }

    /// Total bits actually resident when serving from codes: payloads plus
    /// the (deduplicated) shared codebooks. The §4.4 claim is
    /// `resident_bits ≈ payload_bits` because codebooks amortize.
    pub fn resident_bits(&self) -> u64 {
        self.payload_bits() + self.codebook_bits()
    }

    /// Memory footprint of the quantizable weights in fp32 bits (the §4.4
    /// comparison base).
    pub fn dense_bits(&self) -> u64 {
        self.weights
            .values()
            .map(|w| (w.rows() * w.cols()) as u64 * 32)
            .sum()
    }

    /// Explicitly materialize the dense fake-quant model (one layer at a
    /// time — peak dense residency is a single layer above the artifact).
    pub fn to_dense(&self) -> GptModel {
        let mut tensors = self.fp_tensors.clone();
        let mut dims = self.fp_dims.clone();
        for (name, w) in &self.weights {
            let mut m = Matrix::zeros(w.rows(), w.cols());
            w.dequantize_into(&mut m);
            dims.insert(name.clone(), vec![w.rows(), w.cols()]);
            tensors.insert(name.clone(), m);
        }
        GptModel {
            config: self.config,
            tensors,
            dims,
            name: self.name.clone(),
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::io::Entry;
    use crate::rng::Rng;

    /// Build a synthetic .pct container the loader should accept.
    pub fn synthetic_model_file(path: &Path, d: usize, layers: usize) {
        let mut rng = Rng::new(1);
        let mut pct = Pct::new();
        let ff = d * 4;
        let vocab = 256usize;
        let ctx = 128usize;
        let mut add = |name: &str, dims: &[u64]| {
            let n: u64 = dims.iter().product();
            let mut pctref = Entry::f32(dims, rng.normal_vec(n as usize));
            // keep layernorm gains near 1
            if name.ends_with(".g") {
                if let crate::io::PctData::F32(v) = &mut pctref.data {
                    for x in v.iter_mut() {
                        *x = 1.0;
                    }
                }
            }
            pct.insert(name, pctref);
        };
        add("embed.tok", &[vocab as u64, d as u64]);
        add("embed.pos", &[ctx as u64, d as u64]);
        for i in 0..layers {
            for nm in ["wq", "wk", "wv", "wo"] {
                add(&format!("layer{i}.attn.{nm}"), &[d as u64, d as u64]);
            }
            add(&format!("layer{i}.mlp.w1"), &[d as u64, ff as u64]);
            add(&format!("layer{i}.mlp.w2"), &[ff as u64, d as u64]);
            for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                add(&format!("layer{i}.{nm}"), &[d as u64]);
            }
        }
        add("final_ln.g", &[d as u64]);
        add("final_ln.b", &[d as u64]);
        add("head.w", &[d as u64, vocab as u64]);
        for (k, v) in [
            ("vocab", vocab),
            ("d_model", d),
            ("n_layer", layers),
            ("n_head", 4),
            ("d_ff", ff),
            ("ctx", ctx),
        ] {
            pct.insert(&format!("meta.{k}"), Entry::u64(&[1], vec![v as u64]));
        }
        pct.save(path).unwrap();
    }

    fn tmp_model(name: &str) -> GptModel {
        let dir = std::env::temp_dir().join("pcdvq_model_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.pct"));
        synthetic_model_file(&path, 64, 2);
        GptModel::load(&path).unwrap()
    }

    #[test]
    fn load_synthetic_model() {
        let m = tmp_model("load");
        assert_eq!(m.config.d_model, 64);
        assert_eq!(m.config.n_layer, 2);
        assert_eq!(m.tensor("layer0.attn.wq").unwrap().rows(), 64);
        assert_eq!(m.tensor("layer0.mlp.w1").unwrap().cols(), 256);
        assert!(m.param_count() > 100_000);
    }

    #[test]
    fn fake_quantize_replaces_only_quantizable() {
        let m = tmp_model("fq");
        let rtn = crate::quant::sq::Rtn::new(4);
        let (q, bits) = m.fake_quantize(&rtn);
        assert!(bits > 0);
        // embeddings untouched
        assert_eq!(
            q.tensor("embed.tok").unwrap().as_slice(),
            m.tensor("embed.tok").unwrap().as_slice()
        );
        // quantizable changed
        assert_ne!(
            q.tensor("layer0.attn.wq").unwrap().as_slice(),
            m.tensor("layer0.attn.wq").unwrap().as_slice()
        );
    }

    #[test]
    fn quantizable_vectors_pool_size() {
        let m = tmp_model("pool");
        let pool = m.quantizable_vectors(8);
        assert_eq!(pool.cols(), 8);
        assert_eq!(pool.rows(), m.config.quantizable_params() / 8);
    }

    #[test]
    fn quantized_gpt_payload_accounting() {
        use crate::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook};
        use crate::quant::pcdvq::{Pcdvq, PcdvqConfig};
        use std::sync::Arc;
        let m = tmp_model("qg");
        let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, 8, 8, 0));
        let mag = Arc::new(MagnitudeCodebook::paper_default(2, 8));
        let pcdvq = Pcdvq::new(
            PcdvqConfig { dir_bits: 8, mag_bits: 2, k: 8, seed: 1 },
            dir,
            mag,
        );
        let q = QuantizedGpt::quantize(&m, &pcdvq);
        assert_eq!(q.weights.len(), m.config.quantizable_names().len());
        // 10 bits per 8 weights + metadata ≈ 1.25 bpw + overhead < 32 bpw
        let bpw = q.payload_bits() as f64 / m.config.quantizable_params() as f64;
        assert!(bpw > 1.2 && bpw < 2.0, "bpw={bpw}");
        assert!(q.payload_bits() * 8 < q.dense_bits());
        // one shared DACC codebook pair, counted once across all layers
        assert_eq!(q.codebook_bits(), (256 * 8 * 32 + 4 * 32) as u64);
        assert_eq!(q.resident_bits(), q.payload_bits() + q.codebook_bits());
    }

    #[test]
    fn to_dense_matches_direct_fake_quant() {
        let m = tmp_model("dense");
        let rtn = crate::quant::sq::Rtn::new(3);
        let q = QuantizedGpt::quantize(&m, &rtn);
        let dense = q.to_dense();
        let (fq, bits) = m.fake_quantize(&rtn);
        assert_eq!(bits, q.payload_bits());
        for name in m.config.quantizable_names() {
            assert_eq!(
                dense.tensors[&name].as_slice(),
                fq.tensors[&name].as_slice(),
                "{name}"
            );
        }
        // fp tensors pass through untouched, dims complete
        assert_eq!(
            dense.tensor("embed.tok").unwrap().as_slice(),
            m.tensor("embed.tok").unwrap().as_slice()
        );
        assert_eq!(dense.dims.len(), m.dims.len());
    }
}
