//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! Grammar: `pcdvq <subcommand> [--flag value]...`. Flags are typed at the
//! call site via [`Args::get`]/[`Args::flag`]; unknown flags are rejected so
//! typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + `--key value` pairs + bare `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `std::env::args()[1..]`.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    values.insert(key.to_string(), it.next().unwrap());
                }
                _ => switches.push(key.to_string()),
            }
        }
        Ok(Args { subcommand, values, switches, consumed: Default::default() })
    }

    /// Required value flag.
    pub fn get(&self, key: &str) -> Result<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.values
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("missing required flag --{key}"))
    }

    /// Optional value flag with default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.consumed.borrow_mut().push(key.to_string());
        self.values.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Typed optional flag.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        self.consumed.borrow_mut().push(key.to_string());
        match self.values.get(key) {
            Some(v) => v
                .parse::<T>()
                .with_context(|| format!("--{key}: cannot parse '{v}'")),
            None => Ok(default),
        }
    }

    /// Bare switch (present / absent).
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.switches.iter().any(|s| s == key)
    }

    /// Error on any flag the subcommand never looked at (typo guard). Call
    /// after all `get`/`flag` calls.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.values.keys() {
            if !consumed.contains(k) {
                bail!("unknown flag --{k} for subcommand '{}'", self.subcommand);
            }
        }
        for k in &self.switches {
            if !consumed.contains(k) {
                bail!("unknown switch --{k} for subcommand '{}'", self.subcommand);
            }
        }
        Ok(())
    }
}

/// Usage text for the main binary.
pub const USAGE: &str = "\
pcdvq — Polar Coordinate Decoupled Vector Quantization (paper reproduction)

USAGE: pcdvq <subcommand> [flags]

SUBCOMMANDS
  codebook   build + cache the DACC codebooks
             --dir-bits N (14) --mag-bits N (2)
             --dir-method greedy-e8|random-gaussian|simulated-annealing|kmeans
             --mag-method lloyd-max|kmeans
  quantize   quantize a model, report error decomposition + bpw
             --model NAME (gpt-m) --method SPEC (pcdvq2) --workers N (1)
  eval       perplexity + zero-shot proxy suite for a (quantized) model
             --model NAME --method SPEC|fp16 --windows N (48) --items N (40)
  serve      run the generation service (synthetic traffic, or HTTP
             with --listen)
             --model NAME --quantized --requests N (32) --max-new N (32)
             --listen ADDR  serve HTTP instead of the synthetic loop:
                        POST /v1/generate (SSE token stream + usage
                        record; faults terminate with event: error),
                        GET /metrics (Prometheus text), GET /healthz
                        liveness, GET /readyz readiness (503 while
                        starting or draining); admission gate sheds
                        overload with 429 + Retry-After. Continuous host
                        path, single-node or sharded (e.g. --host
                        --listen 0.0.0.0:8080)
             --read-timeout-ms N  socket read budget per connection
                        (default 30000); dribbling clients get 408
             --host     serve on the host backend (codes-resident with
                        --quantized: packed codes + shared codebooks only,
                        no XLA artifacts, no dense weights); decodes
                        incrementally with per-slot KV caches and, by
                        default, continuous batching + block prefill
             --max-slots N (8)  slot-pool width for continuous batching
             --prefill-chunk K  prompt tokens per block-prefill step
                        (default ctx/4)
             --threads N  worker threads for the per-slot fan-out
                        (default: PALLAS_THREADS or the core count;
                        outputs are identical at every setting)
             --kv-page-size N  tokens per KV page in the block-paged
                        pool (default ctx/8, or PALLAS_KV_PAGE); 0
                        selects the dense per-slot layout — the
                        paged-path parity oracle
             --kv-quant BITS  polar-decoupled KV-cache quantization:
                        cache K/V rows as direction codes + magnitude
                        codes at BITS bits/value (2..=8, even; default
                        PALLAS_KV_QUANT); 0 = exact f32 rows — the
                        quantized-cache parity oracle
             --no-prefix-share  disable cross-request prefix sharing
                        (paged layout only; hot prompts re-prefill)
             --shards N  layer-shard the codes-resident model across N
                        worker nodes (host + --quantized only; codebooks
                        resident once per node; KV-cached decode against
                        node-owned slot caches, honoring --kv-page-size /
                        --kv-quant; --reforward keeps the oracle)
             --static-batch  coalesce into fixed batches instead of
                        continuous admission (the XLA path always does)
             --reforward  disable the KV cache: windowed re-forward every
                        step (the parity oracle; slow; implies static)
  info       print artifact + model inventory

Method SPECs: fp16, rtn2, rtn4, gptq2, kmeans16, quip16, pcdvq2, pcdvq2.125,
pcdvq:a,b.  Tables/figures of the paper: use the `paper` binary.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(&["eval", "--model", "gpt-m", "--quantized", "--windows", "8"]);
        assert_eq!(a.subcommand, "eval");
        assert_eq!(a.get("model").unwrap(), "gpt-m");
        assert!(a.flag("quantized"));
        assert_eq!(a.get_parse_or("windows", 0usize).unwrap(), 8);
        assert_eq!(a.get_or("missing", "dflt"), "dflt");
        a.finish().unwrap();
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["eval".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn finish_rejects_unconsumed() {
        let a = parse(&["eval", "--bogus", "1"]);
        let _ = a.get_or("model", "x");
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["quantize"]);
        assert!(a.get("model").is_err());
    }
}
