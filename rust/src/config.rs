//! Run configuration: artifact paths, quantizer selection, eval sizes.
//!
//! The hand-rolled flag parser lives in [`crate::cli`]; this module holds the
//! typed configuration those flags (and the paper harness) produce.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::codebook::{
    store, DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod,
};
use crate::model::GptModel;
use crate::quant::gptq::GptqLike;
use crate::quant::pcdvq::{Pcdvq, PcdvqConfig};
use crate::quant::quip::QuipLike;
use crate::quant::sq::Rtn;
use crate::quant::vq_kmeans::KMeansVq;
use crate::quant::Quantizer;

/// Where things live on disk.
#[derive(Clone, Debug)]
pub struct Paths {
    pub artifacts: PathBuf,
}

impl Paths {
    /// Default: `$PCDVQ_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn detect() -> Self {
        let artifacts = std::env::var_os("PCDVQ_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        Paths { artifacts }
    }

    pub fn codebook_cache(&self) -> PathBuf {
        self.artifacts.join("codebooks")
    }

    pub fn model(&self, name: &str) -> PathBuf {
        self.artifacts.join(format!("{name}.pct"))
    }

    pub fn eval_tokens(&self) -> Result<Vec<u32>> {
        let pct = crate::io::Pct::load(self.artifacts.join("corpus_eval.pct"))?;
        Ok(pct.get("tokens")?.as_u32()?.to_vec())
    }

    pub fn train_tokens(&self) -> Result<Vec<u32>> {
        let pct = crate::io::Pct::load(self.artifacts.join("corpus_train.pct"))?;
        Ok(pct.get("tokens")?.as_u32()?.to_vec())
    }

    pub fn load_model(&self, name: &str) -> Result<GptModel> {
        GptModel::load(self.model(name))
    }
}

/// Which quantization method a table row refers to.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    Fp16,
    Rtn { bits: u32 },
    GptqLike { bits: u32 },
    KMeansVq { bits: u32 },
    QuipLike { bits: u32 },
    Pcdvq { dir_bits: u32, mag_bits: u32 },
}

impl MethodSpec {
    /// Parse `fp16 | rtn2 | rtn4 | gptq2 | kmeans16 | quip16 | pcdvq2 |
    /// pcdvq2.125 | pcdvq:a,b`.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fp16" | "fp" => MethodSpec::Fp16,
            "pcdvq2" | "pcdvq" => MethodSpec::Pcdvq { dir_bits: 14, mag_bits: 2 },
            "pcdvq2.125" => MethodSpec::Pcdvq { dir_bits: 15, mag_bits: 2 },
            _ => {
                if let Some(b) = s.strip_prefix("rtn") {
                    MethodSpec::Rtn { bits: b.parse()? }
                } else if let Some(b) = s.strip_prefix("gptq") {
                    MethodSpec::GptqLike { bits: b.parse()? }
                } else if let Some(b) = s.strip_prefix("kmeans") {
                    MethodSpec::KMeansVq { bits: b.parse()? }
                } else if let Some(b) = s.strip_prefix("quip") {
                    MethodSpec::QuipLike { bits: b.parse()? }
                } else if let Some(rest) = s.strip_prefix("pcdvq:") {
                    let (a, b) = rest
                        .split_once(',')
                        .ok_or_else(|| anyhow::anyhow!("pcdvq:a,b expected"))?;
                    MethodSpec::Pcdvq { dir_bits: a.parse()?, mag_bits: b.parse()? }
                } else {
                    bail!("unknown method '{s}'")
                }
            }
        })
    }

    pub fn label(&self) -> String {
        match self {
            MethodSpec::Fp16 => "fp16".into(),
            MethodSpec::Rtn { bits } => format!("RTN-{bits}b (GPTQ core)"),
            MethodSpec::GptqLike { bits } => format!("GPTQ-like-{bits}b"),
            MethodSpec::KMeansVq { bits } => format!("KMeansVQ-{bits}b (VPTQ-like)"),
            MethodSpec::QuipLike { bits } => format!("QuIP#-like-{bits}b"),
            MethodSpec::Pcdvq { dir_bits, mag_bits } => {
                format!("PCDVQ a={dir_bits} b={mag_bits}")
            }
        }
    }

    /// Nominal bits per weight.
    pub fn bpw(&self) -> f64 {
        match self {
            MethodSpec::Fp16 => 16.0,
            MethodSpec::Rtn { bits } | MethodSpec::GptqLike { bits } => *bits as f64,
            MethodSpec::KMeansVq { bits } | MethodSpec::QuipLike { bits } => *bits as f64 / 8.0,
            MethodSpec::Pcdvq { dir_bits, mag_bits } => (dir_bits + mag_bits) as f64 / 8.0,
        }
    }

    /// Instantiate the quantizer (building/caching codebooks as needed).
    /// `model` provides the training pool for data-dependent baselines.
    pub fn build(
        &self,
        paths: &Paths,
        model: &GptModel,
        seed: u64,
    ) -> Result<Box<dyn Quantizer + Sync>> {
        Ok(match self {
            MethodSpec::Fp16 => bail!("fp16 is not a quantizer — use the model as-is"),
            MethodSpec::Rtn { bits } => Box::new(Rtn::with_clip_search(*bits)),
            MethodSpec::GptqLike { bits } => Box::new(GptqLike::new(*bits)),
            MethodSpec::KMeansVq { bits } => {
                let mut q = KMeansVq::new(8, *bits);
                q.fit(&model.quantizable_vectors(8));
                Box::new(q)
            }
            MethodSpec::QuipLike { bits } => Box::new(QuipLike::build(*bits, seed)),
            MethodSpec::Pcdvq { dir_bits, mag_bits } => {
                Box::new(build_pcdvq_with(
                    paths,
                    DirectionMethod::GreedyE8,
                    MagnitudeMethod::LloydMax,
                    *dir_bits,
                    *mag_bits,
                    seed,
                )?)
            }
        })
    }
}

/// Build a PCDVQ quantizer with explicit codebook method choices (Table 4).
///
/// Routes through the process-wide [`store::global_registry`], so every
/// quantizer built for the same codebook spec shares one `Arc`'d codebook
/// (disk-cached under `artifacts/codebooks/` as before).
pub fn build_pcdvq_with(
    paths: &Paths,
    dir_method: DirectionMethod,
    mag_method: MagnitudeMethod,
    a: u32,
    b: u32,
    seed: u64,
) -> Result<Pcdvq> {
    let cache = paths.codebook_cache();
    let (dir, mag): (Arc<DirectionCodebook>, Arc<MagnitudeCodebook>) = {
        let mut reg = store::global_registry().lock().unwrap();
        (
            reg.direction(Some(&cache), dir_method, a, 8, 0)?,
            reg.magnitude(Some(&cache), mag_method, b, 8, 0)?,
        )
    };
    Ok(Pcdvq::new(PcdvqConfig { dir_bits: a, mag_bits: b, k: 8, seed }, dir, mag))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_bpw() {
        assert_eq!(MethodSpec::parse("fp16").unwrap(), MethodSpec::Fp16);
        assert_eq!(MethodSpec::parse("rtn2").unwrap().bpw(), 2.0);
        assert_eq!(MethodSpec::parse("kmeans16").unwrap().bpw(), 2.0);
        assert_eq!(MethodSpec::parse("quip17").unwrap().bpw(), 2.125);
        assert_eq!(MethodSpec::parse("pcdvq2").unwrap().bpw(), 2.0);
        assert_eq!(MethodSpec::parse("pcdvq2.125").unwrap().bpw(), 2.125);
        assert_eq!(
            MethodSpec::parse("pcdvq:10,3").unwrap(),
            MethodSpec::Pcdvq { dir_bits: 10, mag_bits: 3 }
        );
        assert!(MethodSpec::parse("bogus").is_err());
    }

    #[test]
    fn labels_distinct() {
        let specs = ["fp16", "rtn2", "gptq2", "kmeans16", "quip16", "pcdvq2"];
        let labels: std::collections::HashSet<String> = specs
            .iter()
            .map(|s| MethodSpec::parse(s).unwrap().label())
            .collect();
        assert_eq!(labels.len(), specs.len());
    }

    #[test]
    fn paths_detect() {
        let p = Paths::detect();
        assert!(p.artifacts.ends_with("artifacts"));
    }
}
