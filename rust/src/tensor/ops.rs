//! Hot-loop kernels over slices: dot products, GEMM, reductions.
//!
//! These are the L3 compute primitives behind codebook construction and
//! direction assignment. They are written so LLVM's autovectorizer produces
//! packed SSE/AVX on the single-core testbed: fixed-width inner chunks,
//! no bounds checks in the inner loop, accumulation in independent lanes.

use super::Matrix;

/// Dot product with 4-lane unrolling (keeps the FP dependency chain short so
/// the autovectorizer can use packed adds).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        // SAFETY-free: slicing keeps bounds checks out of the loop body.
        let (a4, b4) = (&a[i..i + 4], &b[i..i + 4]);
        s0 += a4[0] * b4[0];
        s1 += a4[1] * b4[1];
        s2 += a4[2] * b4[2];
        s3 += a4[3] * b4[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn squared_distance(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Index of the maximum element (first occurrence wins on ties).
#[inline]
pub fn argmax(xs: &[f32]) -> usize {
    debug_assert!(!xs.is_empty());
    let mut best = 0usize;
    let mut best_v = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// `C = A @ B` for row-major matrices. i-k-j loop order so the inner loop is
/// a contiguous AXPY over a row of `B` — the standard cache-friendly layout
/// for row-major GEMM without blocking.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {}x{} @ {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (kk, &aik) in arow.iter().enumerate().take(k) {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// `C = A @ B^T` — both operands row-major, so each output element is a dot
/// of two contiguous rows. This is the layout used by direction assignment
/// (`vectors @ codebook^T`).
pub fn matmul_transposed(a: &Matrix, bt: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        bt.cols(),
        "matmul_transposed inner-dim mismatch: {} vs {}",
        a.cols(),
        bt.cols()
    );
    let (m, n) = (a.rows(), bt.rows());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            crow[j] = dot(arow, bt.row(j));
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(vec![1., 2., 3., 4.], 2, 2);
        let b = Matrix::from_vec(vec![5., 6., 7., 8.], 2, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.as_slice(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec((0..6).map(|x| x as f32).collect(), 2, 3);
        let mut id = Matrix::zeros(3, 3);
        for i in 0..3 {
            id.set(i, i, 1.0);
        }
        assert_eq!(matmul(&a, &id), a);
    }

    #[test]
    fn matmul_transposed_matches_matmul() {
        let a = Matrix::from_vec((0..12).map(|x| (x as f32).sin()).collect(), 3, 4);
        let b = Matrix::from_vec((0..20).map(|x| (x as f32).cos()).collect(), 4, 5);
        let c1 = matmul(&a, &b);
        let c2 = matmul_transposed(&a, &b.transposed());
        for (x, y) in c1.as_slice().iter().zip(c2.as_slice()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_ragged_tail() {
        // length not divisible by 4 exercises the scalar tail
        let a: Vec<f32> = (0..7).map(|x| x as f32).collect();
        let b = vec![1.0f32; 7];
        assert_eq!(dot(&a, &b), 21.0);
    }
}
