//! Row-major `f32` matrix.

use std::fmt;

/// Dense row-major matrix of `f32`.
///
/// This is deliberately tiny: the quantization pipeline treats weights as 2-D
/// arrays and reshapes them into `(n_vectors, k)` groups; everything else
/// (model forward passes) happens inside the AOT-compiled XLA executables.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a flat row-major buffer. Panics if sizes disagree.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix size mismatch");
        Matrix { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Borrow the flat row-major buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Column `j` as an owned vector (columns are strided in row-major).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Overwrite column `j`.
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self.set(i, j, x);
        }
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Reinterpret as a `(len/k, k)` matrix of row vectors — the VQ reshape
    /// from the paper (Eq. 2). Panics unless `k` divides the element count.
    pub fn reshape_vectors(&self, k: usize) -> Matrix {
        assert_eq!(self.len() % k, 0, "k must divide element count");
        Matrix::from_vec(self.data.clone(), self.len() / k, k)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean squared elementwise difference to another same-shaped matrix.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut s = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (a - b) as f64;
            s += d * d;
        }
        s / self.data.len() as f64
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_and_indexing() {
        let m = Matrix::from_vec(vec![1., 2., 3., 4., 5., 6.], 2, 3);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_vec((0..12).map(|x| x as f32).collect(), 3, 4);
        assert_eq!(m.transposed().transposed(), m);
    }

    #[test]
    fn reshape_vectors_shape() {
        let m = Matrix::from_vec((0..16).map(|x| x as f32).collect(), 4, 4);
        let v = m.reshape_vectors(8);
        assert_eq!((v.rows(), v.cols()), (2, 8));
        assert_eq!(v.row(1)[0], 8.0);
    }

    #[test]
    fn mse_zero_on_self() {
        let m = Matrix::from_vec(vec![1., -2., 0.5, 3.], 2, 2);
        assert_eq!(m.mse(&m), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        Matrix::from_vec(vec![1.0; 5], 2, 3);
    }
}
