//! Minimal dense-tensor substrate.
//!
//! No `ndarray` is available offline, and the quantizers only need a small,
//! predictable surface: row-major `f32` matrices with views, GEMM, norms, and
//! a few reductions. Keeping this in-tree also gives the performance pass one
//! hot loop (`matmul`) to own end-to-end.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{argmax, dot, matmul, matmul_transposed, norm2, squared_distance};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert!((norm2(&a) - (14.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn argmax_first_wins_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn squared_distance_zero_on_self() {
        let a = [0.5, -0.25, 8.0];
        assert_eq!(squared_distance(&a, &a), 0.0);
    }
}
