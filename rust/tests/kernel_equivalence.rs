//! Kernel-equivalence suite (its own named CI step): the blocked,
//! LUT-driven `matmul_from_codes` must be **bit-identical** to the scalar
//! reference kernel (`matmul_from_codes_scalar`) for every decoder family,
//! every block size in the grid {1, 7, default, default+1, n_vectors},
//! both LUT modes, **and every thread count** in
//! {1, 2, 4, default_threads + 1} (the parallel column-strip fan-out,
//! DESIGN.md §12) — CI runs the whole suite twice, `PALLAS_THREADS=1` and
//! `=4`, so the default entry point is exercised at both extremes too.
//!
//! Every failure prints a `PCDVQ_PROP_SEED` that reproduces the exact case.

use std::sync::Arc;

use pcdvq::proptest::{for_cases, tiny_pcdvq};
use pcdvq::quant::packing::{PackedIndices, PackedStreams};
use pcdvq::quant::sq::Rtn;
use pcdvq::quant::vq_kmeans::KMeansVq;
use pcdvq::quant::{QuantizedWeight, Quantizer, TableDecoder};
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

/// Bit-pattern view of a matrix, for exact (NaN-safe) equality.
fn bits(m: &Matrix) -> Vec<u32> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// Assert blocked ≡ scalar across the block-size grid, with and without the
/// decode LUT, across the thread grid {1, 2, 4, default_threads + 1}, plus
/// the default entry point.
fn assert_kernels_equal(qw: &QuantizedWeight, x: &Matrix, ctx: &str) {
    let scalar = qw.matmul_from_codes_scalar(x);
    let reference = bits(&scalar);
    let default_block = qw.default_block_vecs();
    let n_vec = qw.n_vectors().max(1);
    for block in [1usize, 7, default_block, default_block + 1, n_vec] {
        for lut in [false, true] {
            let blocked = qw.matmul_from_codes_blocked(x, block, lut);
            assert_eq!(
                reference,
                bits(&blocked),
                "{ctx}: block={block} lut={lut} diverged from scalar kernel"
            );
        }
    }
    // the parallel column-strip fan-out: each worker owns a disjoint slice
    // of y, accumulation order within a column is unchanged — bit-identical
    // at every thread count (n+1 oversubscribes on purpose)
    for threads in [1usize, 2, 4, pcdvq::exec::default_threads() + 1] {
        for lut in [false, true] {
            let par = qw.matmul_from_codes_threaded(x, default_block, lut, threads);
            assert_eq!(
                reference,
                bits(&par),
                "{ctx}: threads={threads} lut={lut} diverged from scalar kernel"
            );
        }
        // an odd block size through the strip walk as well
        let par = qw.matmul_from_codes_threaded(x, 7, true, threads);
        assert_eq!(
            reference,
            bits(&par),
            "{ctx}: threads={threads} block=7 diverged from scalar kernel"
        );
    }
    assert_eq!(
        reference,
        bits(&qw.matmul_from_codes(x)),
        "{ctx}: default kernel diverged from scalar kernel"
    );
}

/// Random table-decoder artifact with arbitrary `k` / shape (the generic
/// coupled-VQ shape).
fn table_artifact(rows: usize, cols: usize, k: usize, bits_w: u32, seed: u64) -> QuantizedWeight {
    assert_eq!(rows * cols % k, 0);
    let n_entries = 1usize << bits_w;
    let mut rng = Rng::new(seed);
    let table = Arc::new(Matrix::from_vec(rng.normal_vec(n_entries * k), n_entries, k));
    let n_vec = rows * cols / k;
    let records: Vec<u64> = (0..n_vec).map(|_| rng.below(n_entries) as u64).collect();
    QuantizedWeight::new(
        "test-table",
        rows,
        cols,
        PackedStreams::single(PackedIndices::pack(&records, bits_w)),
        Arc::new(TableDecoder::new(table, "equiv")),
        Vec::new(),
        None,
    )
}

#[test]
fn pcdvq_rht_seeded_artifact() {
    // the RHT-seeded two-stream path: both kernels share the activation
    // transform, the DACC LUT folds magnitude into direction rows
    let q = tiny_pcdvq();
    let mut rng = Rng::new(0xE0);
    let w = Matrix::from_vec(rng.normal_vec(64 * 32), 64, 32);
    let qw = q.quantize_full(&w);
    assert!(qw.rht_seed().is_some(), "PCDVQ artifacts are RHT-seeded");
    for n in [1usize, 2, 8] {
        let x = Matrix::from_vec(rng.normal_vec(n * 64), n, 64);
        assert_kernels_equal(&qw, &x, &format!("pcdvq rht n={n}"));
    }
}

#[test]
fn scalar_grid_artifact() {
    // k = 1 offset codes with per-column scales (rtn/gptq family)
    let mut rng = Rng::new(0xE1);
    let w = Matrix::from_vec(rng.normal_vec(32 * 24), 32, 24);
    let qw = Rtn::with_clip_search(2).quantize(&w);
    let x = Matrix::from_vec(rng.normal_vec(4 * 32), 4, 32);
    assert_kernels_equal(&qw, &x, "rtn2");
}

#[test]
fn kmeans_table_artifact() {
    // coupled-VQ centroid table doubling as the decode LUT
    let mut rng = Rng::new(0xE2);
    let w = Matrix::from_vec(rng.normal_vec(32 * 32), 32, 32);
    let mut km = KMeansVq::new(8, 6);
    km.fit_on_weight(&w);
    let qw = km.quantize(&w);
    let x = Matrix::from_vec(rng.normal_vec(3 * 32), 3, 32);
    assert_kernels_equal(&qw, &x, "kmeans");
}

#[test]
fn vectors_straddle_weight_rows() {
    // cols not divisible by k: the tile→segment walk must split a decoded
    // vector across two weight rows exactly as the scalar div/mod does
    let mut rng = Rng::new(0xE3);
    for (rows, cols, k) in [(8usize, 6usize, 4usize), (16, 10, 4), (6, 9, 6)] {
        assert_ne!(cols % k, 0, "shape must straddle");
        let qw = table_artifact(rows, cols, k, 5, 0xE30 + rows as u64);
        let x = Matrix::from_vec(rng.normal_vec(2 * rows), 2, rows);
        assert_kernels_equal(&qw, &x, &format!("straddle {rows}x{cols} k={k}"));
    }
}

#[test]
fn one_entry_codebook() {
    // degenerate 1-entry LUT: every record decodes identically
    let k = 4usize;
    let table = Arc::new(Matrix::from_vec(vec![1.5, -0.5, 0.0, 2.0], 1, k));
    let qw = QuantizedWeight::new(
        "one-entry",
        4,
        8,
        PackedStreams::single(PackedIndices::pack(&[0u64; 8], 1)),
        Arc::new(TableDecoder::new(table, "one")),
        vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0],
        None,
    );
    let mut rng = Rng::new(0xE4);
    let x = Matrix::from_vec(rng.normal_vec(3 * 4), 3, 4);
    assert_kernels_equal(&qw, &x, "one-entry");
}

#[test]
fn prop_blocked_equals_scalar_random_shapes() {
    // random shapes, batch sizes, widths and block sizes — the full grid,
    // seeded + reproducible
    for_cases(12, 0xE5, |g| {
        let k = [1usize, 2, 4, 8][g.usize_in(0, 3)];
        let rows = g.usize_in(1, 6) * k;
        let cols = g.usize_in(1, 24);
        let bits_w = g.usize_in(1, 9) as u32;
        // rows*cols must divide by k: rows already does
        let qw = table_artifact(rows, cols, k, bits_w, g.case_seed);
        let n = g.usize_in(1, 5);
        let x = g.matrix(n, rows, 0.02);
        let scalar = qw.matmul_from_codes_scalar(&x);
        let reference = bits(&scalar);
        let block = g.usize_in(1, qw.n_vectors().max(1) + 3);
        for lut in [false, true] {
            let blocked = qw.matmul_from_codes_blocked(&x, block, lut);
            assert_eq!(
                reference,
                bits(&blocked),
                "case={} {rows}x{cols} k={k} n={n} block={block} lut={lut}",
                g.case_seed
            );
        }
        // random thread count through the same case (strips clamp to cols)
        let threads = g.usize_in(1, 9);
        let par = qw.matmul_from_codes_threaded(&x, block, true, threads);
        assert_eq!(
            reference,
            bits(&par),
            "case={} {rows}x{cols} k={k} n={n} block={block} threads={threads}",
            g.case_seed
        );
    });
}
