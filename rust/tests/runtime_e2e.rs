//! Integration: the Rust runtime loads + executes the AOT artifacts and the
//! numbers agree with the L2/L1 semantics.
//!
//! These tests are skipped (cleanly, with a note) when `artifacts/` has not
//! been built — run `make artifacts` first.

use pcdvq::eval::weight_inputs;
use pcdvq::model::GptModel;
use pcdvq::runtime::{Engine, Input};
use pcdvq::tensor::Matrix;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("fwd_fp_gpt-mini_b8.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn fwd_fp_executes_and_produces_finite_logits() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new().unwrap();
    let exe = engine.load(dir.join("fwd_fp_gpt-mini_b8")).unwrap();
    let model = GptModel::load(dir.join("gpt-mini.pct")).unwrap();
    let mut inputs = weight_inputs(&model, &exe.manifest).unwrap();
    let ctx = model.config.ctx;
    inputs.push(Input::I32(vec![65i32; 8 * ctx], vec![8, ctx]));
    let out = exe.run_f32(&inputs).unwrap();
    assert_eq!(out.len(), 8 * ctx * model.config.vocab);
    assert!(out.iter().all(|x| x.is_finite()));
    // the model is trained: logits should be far from uniform
    let mx = out.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mn = out.iter().fold(f32::INFINITY, |m, &x| m.min(x));
    assert!(mx - mn > 2.0, "logit range {mn}..{mx} suspiciously flat");
}

#[test]
fn bound_executable_matches_unbound() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new().unwrap();
    let model = GptModel::load(dir.join("gpt-mini.pct")).unwrap();
    let ctx = model.config.ctx;
    let tokens = Input::I32((0..8 * ctx as i32).map(|i| i % 251).collect(), vec![8, ctx]);

    let exe = engine.load(dir.join("fwd_fp_gpt-mini_b8")).unwrap();
    let weights = weight_inputs(&model, &exe.manifest).unwrap();
    let mut all = weights.clone();
    all.push(tokens.clone());
    let unbound = exe.run_f32(&all).unwrap();

    let exe2 = engine.load(dir.join("fwd_fp_gpt-mini_b8")).unwrap();
    let bound = exe2.bind(&weights, 1).unwrap();
    let bound_out = bound.run_f32(&[tokens]).unwrap();

    assert_eq!(unbound.len(), bound_out.len());
    for (a, b) in unbound.iter().zip(&bound_out) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn assign_chunk_kernel_matches_rust_assigner() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new().unwrap();
    let exe = engine.load(dir.join("assign_chunk")).unwrap();
    // geometry from the manifest
    let ve = exe.manifest.entry("vectors").unwrap().dims.clone();
    let ce = exe.manifest.entry("codebook").unwrap().dims.clone();
    let (n, k, m) = (ve[0], ve[1], ce[0]);

    let mut rng = pcdvq::rng::Rng::new(33);
    let vectors = Matrix::from_vec(rng.normal_vec(n * k), n, k);
    let mut cb = Matrix::from_vec(rng.normal_vec(m * k), m, k);
    for i in 0..m {
        let r = cb.row_mut(i);
        let nrm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        r.iter_mut().for_each(|x| *x /= nrm);
    }

    let out = exe
        .run_i32(&[
            Input::F32(vectors.as_slice().to_vec(), ve),
            Input::F32(cb.as_slice().to_vec(), ce),
        ])
        .unwrap();
    let rust_idx = pcdvq::quant::assign::assign_batch(&vectors, &cb, &[]);
    assert_eq!(out.len(), rust_idx.len());
    let mismatches = out
        .iter()
        .zip(&rust_idx)
        .filter(|(a, b)| **a as u32 != **b)
        .count();
    // ties can break differently between argmax implementations; require
    // essentially-exact agreement
    assert!(
        mismatches * 1000 < n,
        "{mismatches}/{n} assignment mismatches between Pallas kernel and rust"
    );
}

#[test]
fn dequant_kernel_matches_rust_dequant() {
    let Some(dir) = artifacts() else { return };
    use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook};
    use pcdvq::quant::pcdvq::{Pcdvq, PcdvqConfig};
    use std::sync::Arc;

    let engine = Engine::new().unwrap();
    let exe = engine.load(dir.join("dequant_weight")).unwrap();
    let rows = 128usize;
    let cols = 512usize;
    let a = 14u32;

    // quantize a synthetic weight with the real PCDVQ pipeline
    let dir_cb = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, a, 8, 0));
    let mag_cb = Arc::new(MagnitudeCodebook::paper_default(2, 8));
    let pcdvq = Pcdvq::new(
        PcdvqConfig { dir_bits: a, mag_bits: 2, k: 8, seed: 5 },
        dir_cb.clone(),
        mag_cb.clone(),
    );
    let mut rng = pcdvq::rng::Rng::new(44);
    let w = Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols);
    let qw = pcdvq.quantize_full(&w);
    let rust_deq = pcdvq.dequantize_full(&qw);

    // feed the same codes to the Pallas dequant artifact (the packed
    // artifact's two parallel streams are exactly dir_idx / mag_idx)
    let n_vec = qw.n_vectors();
    let dir_stream = qw.codes().stream(0);
    let mag_stream = qw.codes().stream(1);
    let dir_idx: Vec<i32> = (0..n_vec).map(|i| dir_stream.get(i) as i32).collect();
    let mag_idx: Vec<i32> = (0..n_vec).map(|i| mag_stream.get(i) as i32).collect();
    let signs =
        pcdvq::hadamard::RandomizedHadamard::new(rows, qw.rht_seed().expect("PCDVQ uses RHT"));
    let out = exe
        .run_f32(&[
            Input::I32(dir_idx, vec![n_vec]),
            Input::I32(mag_idx, vec![n_vec]),
            Input::F32(dir_cb.vectors.as_slice().to_vec(), vec![1 << a, 8]),
            Input::F32(mag_cb.levels.clone(), vec![4]),
            Input::F32(qw.scales().to_vec(), vec![cols]),
            Input::F32(signs.signs().to_vec(), vec![rows]),
        ])
        .unwrap();
    assert_eq!(out.len(), rows * cols);
    let mut max_diff = 0.0f32;
    let mut bad_rows = std::collections::BTreeSet::new();
    let mut bad_cols = std::collections::BTreeSet::new();
    for (i, (a, b)) in out.iter().zip(rust_deq.as_slice()).enumerate() {
        let d = (a - b).abs();
        if d > 1e-3 {
            bad_rows.insert(i / cols);
            bad_cols.insert(i % cols);
        }
        max_diff = max_diff.max(d);
    }
    assert!(
        max_diff < 1e-4,
        "pallas vs rust dequant max diff {max_diff}; bad rows {} ({:?}...), bad cols {} ({:?}...)",
        bad_rows.len(),
        bad_rows.iter().take(8).collect::<Vec<_>>(),
        bad_cols.len(),
        bad_cols.iter().take(8).collect::<Vec<_>>()
    );
}
