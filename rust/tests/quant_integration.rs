//! Cross-module integration: the whole quantizer zoo on realistic weights,
//! error orderings the paper's tables rely on, and the RHT/PCD pipeline
//! glued together.

use std::sync::Arc;

use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use pcdvq::quant::error::decompose_weights;
use pcdvq::quant::pcdvq::{Pcdvq, PcdvqConfig};
use pcdvq::quant::quip::QuipLike;
use pcdvq::quant::sq::Rtn;
use pcdvq::quant::vq_kmeans::KMeansVq;
use pcdvq::quant::Quantizer;
use pcdvq::rng::Rng;
use pcdvq::tensor::{matmul, Matrix};

/// Heavy-tailed weight: Gaussian body + outliers, like real LLM layers.
fn realistic_weight(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut data = rng.normal_vec(rows * cols);
    for (i, x) in data.iter_mut().enumerate() {
        if i % 997 == 0 {
            *x *= 20.0;
        }
    }
    Matrix::from_vec(data, rows, cols)
}

fn pcdvq(a: u32, b: u32) -> Pcdvq {
    let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, a, 8, 0));
    let mag = Arc::new(MagnitudeCodebook::build(
        MagnitudeMethod::LloydMax,
        b,
        8,
        1.0 - 1e-4,
        0,
    ));
    Pcdvq::new(PcdvqConfig { dir_bits: a, mag_bits: b, k: 8, seed: 7 }, dir, mag)
}

#[test]
fn paper_ordering_on_reconstruction_error() {
    // Table 1's core shape at the weight level: PCDVQ and coupled VQ beat
    // SQ at ~2 bpw on heavy-tailed weights (RHT gives PCDVQ robustness).
    let w = realistic_weight(256, 256, 1);

    let e_pcdvq = pcdvq(12, 2).quantize(&w).dequantize().mse(&w);

    let mut km = KMeansVq::new(8, 14); // same 14-bit index budget
    km.fit_on_weight(&w);
    let e_km = km.quantize(&w).dequantize().mse(&w);

    let e_rtn = Rtn::with_clip_search(2).quantize(&w).dequantize().mse(&w);

    assert!(
        e_pcdvq < e_rtn,
        "pcdvq {e_pcdvq} must beat 2-bit SQ {e_rtn} on heavy-tailed weights"
    );
    assert!(e_km < e_rtn, "coupled VQ {e_km} must beat 2-bit SQ {e_rtn}");
}

#[test]
fn rht_immunizes_pcdvq_against_outliers() {
    // without outliers
    let mut rng = Rng::new(5);
    let clean = Matrix::from_vec(rng.normal_vec(128 * 128), 128, 128);
    let q = pcdvq(10, 2);
    let e_clean = q.quantize(&clean).dequantize().mse(&clean);
    // with outliers: the *relative* error should not explode
    let dirty = realistic_weight(128, 128, 6);
    let e_dirty = q.quantize(&dirty).dequantize().mse(&dirty);
    let var_dirty: f64 = dirty
        .as_slice()
        .iter()
        .map(|&x| (x as f64).powi(2))
        .sum::<f64>()
        / dirty.len() as f64;
    assert!(
        e_dirty / var_dirty < 2.5 * e_clean,
        "relative error exploded: clean {e_clean}, dirty {e_dirty} (var {var_dirty})"
    );
}

#[test]
fn pcdvq_error_split_vs_coupled_vq() {
    // Fig 3, as measured on this substrate (see EXPERIMENTS.md): at equal
    // index budget PCDVQ's *magnitude* error is far below the coupled
    // baseline's (Lloyd-Max vs coupled radial granularity) and its *total*
    // decomposed error is not worse. Decomposition must happen in the
    // regularized domain — the inverse RHT is a rotation that would
    // isotropize the split.
    let w = realistic_weight(128, 512, 7);
    let q8 = QuipLike::build(14, 3);
    let (h_c, hq_c) = q8.quantize_regularized(&w);
    let d_coupled = decompose_weights(&h_c, &hq_c, 8);

    let q = pcdvq(12, 2); // same 14-bit budget
    let (h_p, hq_p) = q.quantize_regularized(&w);
    let d_pcdvq = decompose_weights(&h_p, &hq_p, 8);

    assert!(
        d_pcdvq.magnitude_mse < d_coupled.magnitude_mse,
        "decoupled magnitude error should be smaller: {} vs {}",
        d_pcdvq.magnitude_mse,
        d_coupled.magnitude_mse
    );
    let total_p = d_pcdvq.magnitude_mse + d_pcdvq.direction_cross_mse;
    let total_c = d_coupled.magnitude_mse + d_coupled.direction_cross_mse;
    assert!(
        total_p < total_c * 1.10,
        "PCDVQ total error should not lose at equal budget: {total_p} vs {total_c}"
    );
}

#[test]
fn bits_allocation_monotonicity() {
    // more direction bits at fixed magnitude bits must reduce error
    let w = realistic_weight(128, 128, 9);
    let mut last = f64::INFINITY;
    for a in [6u32, 8, 10, 12] {
        let e = pcdvq(a, 2).quantize(&w).dequantize().mse(&w);
        assert!(e < last, "a={a}: {e} not < {last}");
        last = e;
    }
}

#[test]
fn quantizers_preserve_shape_and_finiteness() {
    let w = realistic_weight(128, 64, 11);
    let quantizers: Vec<Box<dyn Quantizer>> = vec![
        Box::new(Rtn::new(2)),
        Box::new(Rtn::with_clip_search(3)),
        Box::new(pcdvq::quant::gptq::GptqLike::new(2)),
        Box::new(pcdvq(8, 2)),
        Box::new(QuipLike::build(10, 1)),
    ];
    for q in quantizers {
        let out = q.quantize(&w);
        assert!(out.payload_bits() > 0);
        let deq = out.dequantize();
        assert_eq!((deq.rows(), deq.cols()), (128, 64), "{}", q.name());
        assert!(
            deq.as_slice().iter().all(|x| x.is_finite()),
            "{} produced non-finite values",
            q.name()
        );
    }
}

#[test]
fn fused_matmul_matches_dequantize_path_for_every_quantizer() {
    // The serving-path contract of the compressed-artifact representation:
    // matmul_from_codes (gather → scale → inverse-FWHT, no dense weight)
    // must agree with explicit dequantize_into + dense matmul within 1e-5
    // for every quantizer in the zoo.
    let w = realistic_weight(64, 32, 21);
    let mut km = KMeansVq::new(8, 10);
    km.fit_on_weight(&w);
    let quantizers: Vec<Box<dyn Quantizer>> = vec![
        Box::new(pcdvq(9, 2)),
        Box::new(Rtn::with_clip_search(2)),
        Box::new(pcdvq::quant::gptq::GptqLike::new(2)),
        Box::new(km),
        Box::new(QuipLike::build(10, 1)),
    ];
    let mut rng = Rng::new(22);
    let x = Matrix::from_vec(rng.normal_vec(4 * 64), 4, 64);
    for q in quantizers {
        let qw = q.quantize(&w);
        let mut dense = Matrix::zeros(64, 32);
        qw.dequantize_into(&mut dense);
        let reference = matmul(&x, &dense);
        let fused = qw.matmul_from_codes(&x);
        assert_eq!((fused.rows(), fused.cols()), (4, 32), "{}", q.name());
        for (i, (a, b)) in reference.as_slice().iter().zip(fused.as_slice()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs())),
                "{}: elem {i} fused {b} vs dense {a}",
                q.name()
            );
        }
        // and the matvec agrees with row 0 of the batched kernel
        let y = qw.matvec_from_codes(x.row(0));
        for (a, b) in fused.row(0).iter().zip(&y) {
            assert!((a - b).abs() < 1e-6, "{}: matvec disagrees", q.name());
        }
    }
}

#[test]
fn artifacts_are_compressed_not_dense() {
    // every quantizer's artifact must be an order of magnitude smaller than
    // the fp32 weight it encodes (the whole point of the refactor)
    let w = realistic_weight(128, 64, 23);
    let mut km = KMeansVq::new(8, 12);
    km.fit_on_weight(&w);
    let quantizers: Vec<Box<dyn Quantizer>> = vec![
        Box::new(pcdvq(10, 2)),
        Box::new(Rtn::new(2)),
        Box::new(pcdvq::quant::gptq::GptqLike::new(2)),
        Box::new(km),
        Box::new(QuipLike::build(12, 1)),
    ];
    let dense_bits = (w.len() * 32) as u64;
    for q in quantizers {
        let qw = q.quantize(&w);
        assert!(
            qw.payload_bits() * 8 <= dense_bits,
            "{}: payload {} vs dense {dense_bits}",
            q.name(),
            qw.payload_bits()
        );
        // payload accounting matches the packed streams exactly
        let meta = qw.scales().len() as u64 * 32
            + if qw.rht_seed().is_some() { 64 } else { 0 };
        assert_eq!(qw.payload_bits(), qw.codes().payload_bits() + meta, "{}", q.name());
    }
}

#[test]
fn codebooks_shared_across_layers_give_consistent_results() {
    // the same Pcdvq instance must quantize different shapes fine
    let q = pcdvq(9, 2);
    for (r, c) in [(64usize, 64usize), (128, 32), (256, 8), (64, 256)] {
        let w = realistic_weight(r, c, (r * 31 + c) as u64);
        let qw = q.quantize_full(&w);
        assert_eq!(qw.n_vectors(), r * c / 8);
        let deq = q.dequantize_full(&qw);
        assert_eq!((deq.rows(), deq.cols()), (r, c));
        let var: f64 = w.as_slice().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / w.len() as f64;
        let rel = deq.mse(&w) / var;
        assert!(rel < 1.0, "({r},{c}): relative error {rel} >= 1");
    }
}
