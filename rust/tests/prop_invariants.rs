//! Property-based invariants (hand-rolled helper — see `pcdvq::proptest`).
//!
//! Every failure prints a `PCDVQ_PROP_SEED` that reproduces the exact case.

use std::sync::Arc;

use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use pcdvq::hadamard::{deregularize, fwht_normalized, regularize, RandomizedHadamard};
use pcdvq::proptest::for_cases;
use pcdvq::quant::assign::{assign_batch, assign_euclidean};
use pcdvq::quant::error::decompose;
use pcdvq::quant::packing::{splice, unsplice, PackedIndices, PackedStreams};
use pcdvq::quant::pcdvq::{Pcdvq, PcdvqConfig};
use pcdvq::stats::ChiDistribution;
use pcdvq::tensor::{dot, squared_distance};

#[test]
fn prop_fwht_is_isometry_and_involution() {
    for_cases(25, 0xA1, |g| {
        let n = g.pow2_in(8, 512);
        let mut x = g.rng.normal_vec(n);
        let orig = x.clone();
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        fwht_normalized(&mut x);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() / norm0.max(1e-6) < 1e-3, "norm not preserved");
        fwht_normalized(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-3, "involution violated");
        }
    });
}

#[test]
fn prop_regularize_round_trips() {
    for_cases(20, 0xB2, |g| {
        let rows = g.pow2_in(16, 256);
        let cols = g.usize_in(1, 24);
        let w = g.matrix(rows, cols, 0.02);
        let rht = RandomizedHadamard::new(rows, g.case_seed);
        let (h, scales) = regularize(&w, &rht);
        let back = deregularize(&h, &scales, &rht);
        assert!(back.mse(&w) < 1e-6, "round trip mse {}", back.mse(&w));
    });
}

#[test]
fn prop_packing_bijective() {
    for_cases(30, 0xC3, |g| {
        let width = g.usize_in(1, 40) as u32;
        let n = g.usize_in(1, 500);
        let mask = if width >= 63 { u64::MAX >> 1 } else { (1u64 << width) - 1 };
        let values: Vec<u64> = (0..n).map(|_| g.rng.next_u64() & mask).collect();
        let packed = PackedIndices::pack(&values, width);
        assert_eq!(packed.unpack(), values);
        // random access agrees
        for _ in 0..10.min(n) {
            let i = g.rng.below(n);
            assert_eq!(packed.get(i), values[i]);
        }
    });
}

#[test]
fn prop_packing_extreme_widths() {
    // width 1 (bitmap) and width 63 (max) are the boundary geometries: a
    // 1-bit stream packs 64 records per word, a 63-bit stream straddles a
    // word boundary on almost every record.
    for_cases(25, 0xC4, |g| {
        let n = g.usize_in(1, 700);
        let ones: Vec<u64> = (0..n).map(|_| g.rng.next_u64() & 1).collect();
        let p1 = PackedIndices::pack(&ones, 1);
        assert_eq!(p1.unpack(), ones, "width 1");
        assert_eq!(p1.payload_bits(), n as u64);

        let wide: Vec<u64> = (0..n).map(|_| g.rng.next_u64() >> 1).collect();
        let p63 = PackedIndices::pack(&wide, 63);
        assert_eq!(p63.unpack(), wide, "width 63");
        for _ in 0..8.min(n) {
            let i = g.rng.below(n);
            assert_eq!(p63.get(i), wide[i]);
        }
    });
}

#[test]
fn prop_packing_cross_word_boundaries() {
    // widths that do not divide 64 force records to straddle u64 words;
    // every record adjacent to a 64-bit boundary must survive the split.
    for_cases(30, 0xC5, |g| {
        let width = [3u32, 5, 7, 11, 13, 17, 23, 29, 31, 37, 41, 53, 61]
            [g.usize_in(0, 12)];
        let n = g.usize_in(2, 400);
        let mask = (1u64 << width) - 1;
        let values: Vec<u64> = (0..n).map(|_| g.rng.next_u64() & mask).collect();
        let packed = PackedIndices::pack(&values, width);
        // every record that straddles a word boundary reads back exactly
        for i in 0..n {
            let start = i as u64 * width as u64;
            let end = start + width as u64;
            if start / 64 != (end - 1) / 64 {
                assert_eq!(packed.get(i), values[i], "straddling record {i} w={width}");
            }
        }
        assert_eq!(packed.unpack(), values);
        // round trip through the raw words (the persistence path)
        let rebuilt =
            PackedIndices::from_words(packed.words().to_vec(), width, n);
        assert_eq!(rebuilt, packed);
    });
}

#[test]
fn prop_multi_stream_records_consistent() {
    for_cases(20, 0xC6, |g| {
        let n = g.usize_in(1, 300);
        let wa = g.usize_in(1, 20) as u32;
        let wb = g.usize_in(1, 8) as u32;
        let a: Vec<u64> = (0..n).map(|_| g.rng.next_u64() & ((1 << wa) - 1)).collect();
        let b: Vec<u64> = (0..n).map(|_| g.rng.next_u64() & ((1 << wb) - 1)).collect();
        let s = PackedStreams::new(vec![
            PackedIndices::pack(&a, wa),
            PackedIndices::pack(&b, wb),
        ]);
        assert_eq!(s.payload_bits(), n as u64 * (wa + wb) as u64);
        let mut rec = [0u64; 2];
        for i in 0..n {
            s.records_into(i, &mut rec);
            assert_eq!(rec, [a[i], b[i]]);
        }
    });
}

#[test]
fn prop_fused_matmul_matches_dequantize_path() {
    // serving contract: x·Ŵ straight from the codes ≡ x·dequantize(Ŵ)
    // within 1e-5, across random shapes and bit budgets
    let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, 8, 8, 0));
    let mag = Arc::new(MagnitudeCodebook::build(MagnitudeMethod::LloydMax, 2, 8, 1.0 - 1e-4, 0));
    for_cases(10, 0xC7, |g| {
        let rows = g.pow2_in(16, 128);
        let cols = g.usize_in(1, 4) * 8;
        let w = g.matrix(rows, cols, 0.01);
        let q = Pcdvq::new(
            PcdvqConfig { dir_bits: 8, mag_bits: 2, k: 8, seed: g.case_seed },
            dir.clone(),
            mag.clone(),
        );
        let qw = q.quantize_full(&w);
        let n = g.usize_in(1, 3);
        let x = pcdvq::tensor::Matrix::from_vec(g.rng.normal_vec(n * rows), n, rows);
        let mut dense = pcdvq::tensor::Matrix::zeros(rows, cols);
        qw.dequantize_into(&mut dense);
        let reference = pcdvq::tensor::matmul(&x, &dense);
        let fused = qw.matmul_from_codes(&x);
        for (a, b) in reference.as_slice().iter().zip(fused.as_slice()) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs())),
                "case {}: fused {b} vs dense {a}",
                g.case_seed
            );
        }
    });
}

#[test]
fn prop_splice_bijective() {
    for_cases(40, 0xD4, |g| {
        let a = g.usize_in(1, 24) as u32;
        let b = g.usize_in(1, 8) as u32;
        let d = (g.rng.next_u64() & ((1 << a) - 1)) as u32;
        let m = (g.rng.next_u64() & ((1 << b) - 1)) as u32;
        assert_eq!(unsplice(splice(d, m, a), a), (d, m));
    });
}

#[test]
fn prop_assignment_is_optimal() {
    // no codebook row may score higher than the assigned one
    for_cases(15, 0xE5, |g| {
        let n = g.usize_in(1, 60);
        let m = g.usize_in(2, 700);
        let k = [2, 4, 8, 8, 16][g.usize_in(0, 4)];
        let vectors = g.matrix(n, k, 0.0);
        let cb = g.unit_vectors(m, k);
        let idx = assign_batch(&vectors, &cb, &[]);
        for i in 0..n {
            let s_assigned = dot(vectors.row(i), cb.row(idx[i] as usize));
            for j in 0..m {
                assert!(
                    dot(vectors.row(i), cb.row(j)) <= s_assigned + 1e-4,
                    "case {}: better codeword exists",
                    g.case_seed
                );
            }
        }
    });
}

#[test]
fn prop_euclidean_assignment_is_nearest() {
    for_cases(12, 0xF6, |g| {
        let n = g.usize_in(1, 40);
        let m = g.usize_in(2, 400);
        let vectors = g.matrix(n, 8, 0.0);
        let cb = g.matrix(m, 8, 0.0);
        let idx = assign_euclidean(&vectors, &cb);
        for i in 0..n {
            let d_assigned = squared_distance(vectors.row(i), cb.row(idx[i] as usize));
            for j in 0..m {
                assert!(
                    squared_distance(vectors.row(i), cb.row(j)) >= d_assigned - 1e-3,
                    "closer codeword exists"
                );
            }
        }
    });
}

#[test]
fn prop_pcdvq_error_bounded_by_covering() {
    // dequant(quant(w)) error per vector is bounded by (covering angle
    // error + magnitude cell width); we check the aggregate is bounded by
    // the unit variance — i.e. quantization never *adds* energy on average.
    let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, 10, 8, 0));
    let mag = Arc::new(MagnitudeCodebook::build(MagnitudeMethod::LloydMax, 2, 8, 1.0 - 1e-4, 0));
    for_cases(10, 0x17, |g| {
        let rows = g.pow2_in(32, 128);
        let cols = g.usize_in(1, 3) * 8;
        let w = g.matrix(rows, cols, 0.01);
        let q = Pcdvq::new(
            PcdvqConfig { dir_bits: 10, mag_bits: 2, k: 8, seed: g.case_seed },
            dir.clone(),
            mag.clone(),
        );
        let deq = q.dequantize_full(&q.quantize_full(&w));
        let var: f64 = w.as_slice().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / w.len() as f64;
        let rel = deq.mse(&w) / var.max(1e-9);
        assert!(rel < 0.9, "relative error {rel} out of bound");
    });
}

#[test]
fn prop_eq5_decomposition_identity() {
    // ‖v−c‖² == Δr² + 2‖v‖‖c‖(1−cosθ) for arbitrary vector pairs (Eq. 5)
    for_cases(25, 0x28, |g| {
        let n = g.usize_in(1, 50);
        let v = g.matrix(n, 8, 0.05);
        let mut c = v.clone();
        for x in c.as_mut_slice() {
            *x += 0.3 * g.rng.normal() as f32;
        }
        let d = decompose(&v, &c);
        let recon = d.magnitude_mse + d.direction_cross_mse;
        let denom = d.total_mse.max(1e-12);
        assert!(
            ((recon - d.total_mse) / denom).abs() < 5e-3,
            "Eq.5 identity violated: {recon} vs {}",
            d.total_mse
        );
    });
}

#[test]
fn prop_chi_cdf_monotone_and_quantile_inverse() {
    for_cases(20, 0x39, |g| {
        let k = g.usize_in(1, 32);
        let chi = ChiDistribution::new(k);
        let r1 = g.f32_in(0.0, 4.0) as f64;
        let r2 = r1 + g.f32_in(0.001, 3.0) as f64;
        assert!(chi.cdf(r2) >= chi.cdf(r1));
        let p = g.f32_in(0.01, 0.99) as f64;
        let r = chi.quantile(p);
        assert!((chi.cdf(r) - p).abs() < 1e-6);
    });
}

#[test]
fn prop_magnitude_assignment_nearest_level() {
    let mag = MagnitudeCodebook::build(MagnitudeMethod::LloydMax, 4, 8, 1.0 - 1e-4, 0);
    for_cases(30, 0x4A, |g| {
        let r = g.f32_in(0.0, 8.0);
        let idx = mag.assign(r) as usize;
        for (j, &l) in mag.levels.iter().enumerate() {
            assert!(
                (r - mag.levels[idx]).abs() <= (r - l).abs() + 1e-5,
                "level {j} closer than assigned {idx} for r={r}"
            );
        }
    });
}
