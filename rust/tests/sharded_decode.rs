//! Sharded KV-cached decode vs the cross-topology parity matrix.
//!
//! The contract (DESIGN.md §16): `Server::serve_continuous` on the sharded
//! backend — node-owned per-slot KV state, activations pipelined through
//! `ShardedForward::step_slots` — produces **token-identical** per-request
//! outputs to the single-node host path at every cell of
//! shards {1,2,3} × kv_page {0,4} × kv_quant {0,4}, for greedy *and*
//! sampled decodes, including sequences that straddle the slide+rebuild
//! eviction boundary. The windowed re-forward survives as the sharded
//! parity oracle (`DecodePolicy::Reforward`), and the §12 determinism
//! contract (outputs and metrics invariant under `PALLAS_THREADS`) extends
//! to shard count: named CI steps run this suite at 1 and 4 threads.
//!
//! Plus: `shard_layers` obeys the partition contract property-wise,
//! interleaved multi-request traffic is token-identical and leak-free per
//! node, per-node resident-bit accounting partitions (KV grids sum to the
//! single-node codec; `paper::verify_kv_cache_resident` holds on the
//! sharded backend), and prefix sharing engages symmetrically across
//! topologies.

use std::sync::mpsc::channel;

use pcdvq::coordinator::{
    shard_layers, Batcher, BatcherConfig, DecodePolicy, GenRequest, GenResponse, Server,
    ServingWeights,
};
use pcdvq::model::{GptConfig, GptModel, QuantizedGpt};
use pcdvq::proptest::{for_cases, synthetic_tinygpt, tiny_pcdvq};

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the sharded-decode testbed.
fn synthetic_model(name: &str) -> GptModel {
    synthetic_tinygpt("pcdvq_shard_decode_tests", name, 23)
}

fn quantize(model: &GptModel) -> QuantizedGpt {
    QuantizedGpt::quantize(model, &tiny_pcdvq())
}

fn prompt_bytes(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 11 + salt * 17 + 3) % 251) as u8).collect()
}

/// One cell of the topology matrix. `kv_page == 0` selects the dense
/// per-slot layout, `kv_quant == 0` the exact (unquantized) cache.
struct Cell {
    shards: usize,
    kv_page: usize,
    kv_quant: u32,
}

impl Cell {
    fn tag(&self) -> String {
        format!("shards={} kv_page={} kv_quant={}", self.shards, self.kv_page, self.kv_quant)
    }
}

/// Serve `reqs` = (prompt, max_new, temperature) through the continuous
/// loop at one matrix cell — all requests pre-queued so admission order
/// (and therefore `request_rng` seeding) is deterministic.
fn run_continuous(
    q: &QuantizedGpt,
    cell: &Cell,
    max_slots: usize,
    prefill_chunk: usize,
    threads: Option<usize>,
    prefix_share: Option<bool>,
    reqs: &[(Vec<u8>, usize, f32)],
) -> (Vec<GenResponse>, Server) {
    let mut b = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .shards(cell.shards)
        .kv_page(cell.kv_page)
        .kv_quant(cell.kv_quant)
        .max_slots(max_slots)
        .prefill_chunk(prefill_chunk);
    if let Some(t) = threads {
        b = b.threads(t);
    }
    if let Some(share) = prefix_share {
        b = b.prefix_share(share);
    }
    let mut server = b.build().unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rxs = Vec::new();
    for (p, max_new, temp) in reqs {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
        rxs.push(rrx);
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps = rxs.iter().map(|r| r.recv().expect("response missing")).collect();
    (resps, server)
}

/// Single-request run through the static path under `policy` at `shards`
/// nodes — the oracle helper (`Reforward` on the sharded backend is the
/// windowed re-forward parity oracle, DESIGN.md §16).
fn run_single(
    q: &QuantizedGpt,
    shards: usize,
    policy: DecodePolicy,
    prompt: &[u8],
    max_new: usize,
) -> Vec<u8> {
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .shards(shards)
        .decode(policy)
        .build()
        .unwrap();
    let (rtx, rrx) = channel();
    server
        .process_batch(vec![GenRequest::builder(prompt.to_vec()).max_new(max_new).build(rtx)])
        .unwrap();
    rrx.recv().unwrap().generated
}

/// The headline matrix: one mixed greedy/sampled request set (including
/// eviction-straddling lengths) served at every cell of
/// shards {1,2,3} × kv_page {0,4} × kv_quant {0,4}. Within a `kv_quant`
/// class every cell must produce byte-identical per-request tokens —
/// sharding and the page layout are pure implementation choices; only the
/// cache codec may move logits.
#[test]
fn sharded_continuous_matches_the_cross_topology_matrix() {
    let model = synthetic_model("matrix");
    let ctx = model.config.ctx;
    let q = quantize(&model);

    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (prompt_bytes(1, 0), 6, 0.0),
        (prompt_bytes(ctx / 2, 1), 8, 0.0),
        // prompt + max_new > ctx: crosses the slide+rebuild eviction boundary
        (prompt_bytes(ctx - 8, 2), 30, 0.0),
        (prompt_bytes(5, 3), 8, 0.8),
        // sampled + eviction-straddling
        (prompt_bytes(ctx - 4, 4), 24, 0.7),
    ];

    for kv_quant in [0u32, 4] {
        let mut baseline: Option<Vec<Vec<u8>>> = None;
        for shards in [1usize, 2, 3] {
            for kv_page in [0usize, 4] {
                let cell = Cell { shards, kv_page, kv_quant };
                let (resps, server) = run_continuous(&q, &cell, 3, 5, None, None, &reqs);
                assert_eq!(
                    server.metrics.requests as usize,
                    reqs.len(),
                    "{}: request count",
                    cell.tag()
                );
                assert!(server.metrics.decode_steps > 0, "{}: decoded KV-cached", cell.tag());
                let toks: Vec<Vec<u8>> = resps.iter().map(|r| r.generated.clone()).collect();
                match &baseline {
                    None => baseline = Some(toks),
                    Some(want) => {
                        for (i, (got, want)) in toks.iter().zip(want).enumerate() {
                            assert_eq!(
                                got,
                                want,
                                "req {i} at {} diverged from the single-node dense cell",
                                cell.tag()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Greedy sharded continuous decodes equal both oracles token-for-token
/// while the window fits in ctx (trunc + max_new ≤ ctx + 1, where the
/// cached and re-forward schedules coincide, DESIGN.md §9): the sharded
/// static `Reforward` path and the single-node host KV-cached path.
#[test]
fn sharded_decode_matches_reforward_and_host_cached_oracles() {
    let model = synthetic_model("oracle");
    let ctx = model.config.ctx;
    let q = quantize(&model);

    let cases: Vec<(usize, usize)> = vec![(1, 6), (ctx / 2, 6), (ctx - 9, 8)];
    let reqs: Vec<(Vec<u8>, usize, f32)> = cases
        .iter()
        .enumerate()
        .map(|(i, &(plen, max_new))| (prompt_bytes(plen, i), max_new, 0.0))
        .collect();

    for shards in [2usize, 3] {
        // exact-cache cells only: the re-forward oracle never quantizes
        for kv_page in [0usize, 4] {
            let cell = Cell { shards, kv_page, kv_quant: 0 };
            let (resps, _) = run_continuous(&q, &cell, 2, 7, None, None, &reqs);
            for (i, (prompt, max_new, _)) in reqs.iter().enumerate() {
                let reforward =
                    run_single(&q, shards, DecodePolicy::Reforward, prompt, *max_new);
                let host_cached =
                    run_single(&q, 1, DecodePolicy::KvCached, prompt, *max_new);
                assert_eq!(
                    resps[i].generated,
                    reforward,
                    "req {i} at {}: vs sharded re-forward oracle",
                    cell.tag()
                );
                assert_eq!(
                    resps[i].generated,
                    host_cached,
                    "req {i} at {}: vs single-node cached oracle",
                    cell.tag()
                );
            }
        }
    }
}

/// §12 determinism extended to the sharded backend: explicit 1- vs
/// 4-thread runs of the same traffic (paged + quantized cell, sampled +
/// eviction-straddling requests) produce identical tokens, per-request
/// steps, and scheduler/cache counters.
#[test]
fn sharded_outputs_and_metrics_invariant_under_thread_count() {
    let model = synthetic_model("threads");
    let ctx = model.config.ctx;
    let q = quantize(&model);

    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (prompt_bytes(7, 0), 9, 0.0),
        (prompt_bytes(ctx - 6, 1), 26, 0.0),
        (prompt_bytes(19, 2), 12, 0.9),
    ];
    let cell = Cell { shards: 3, kv_page: 4, kv_quant: 4 };
    let (r1, s1) = run_continuous(&q, &cell, 3, 5, Some(1), None, &reqs);
    let (r4, s4) = run_continuous(&q, &cell, 3, 5, Some(4), None, &reqs);
    for (i, (a, b)) in r1.iter().zip(&r4).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: tokens moved with thread count");
        assert_eq!(a.steps, b.steps, "req {i}: steps moved with thread count");
    }
    assert_eq!(s1.metrics.decode_steps, s4.metrics.decode_steps, "decode steps");
    assert_eq!(s1.metrics.tokens_generated, s4.metrics.tokens_generated, "tokens");
    assert_eq!(s1.metrics.slot_steps_busy, s4.metrics.slot_steps_busy, "occupancy");
    assert_eq!(s1.metrics.slot_steps_total, s4.metrics.slot_steps_total, "occupancy total");
    assert_eq!(s1.metrics.kv_decoded_subvecs, s4.metrics.kv_decoded_subvecs, "codec reads");
    assert_eq!(s1.metrics.kv_pages_allocated, s4.metrics.kv_pages_allocated, "pool allocs");
    assert_eq!(s1.metrics.kv_cache_resident_bits, s4.metrics.kv_cache_resident_bits, "bits");
}

/// Per-node resident-bit accounting partitions: slot-cache bits and KV
/// codebook bits per node sum to the server totals, the summed grids equal
/// a single-node codec's codebooks (grids are built once per layer,
/// wherever the layer lives), and the paper-grade resident verifiers hold
/// on the sharded backend.
#[test]
fn sharded_resident_accounting_partitions_across_nodes() {
    let model = synthetic_model("bits");
    let q = quantize(&model);
    let n_nodes = shard_layers(&model.config, 2).len();

    let reqs: Vec<(Vec<u8>, usize, f32)> =
        vec![(prompt_bytes(20, 0), 10, 0.0), (prompt_bytes(33, 1), 12, 0.0)];
    let cell = Cell { shards: 2, kv_page: 4, kv_quant: 4 };
    let (_, server) = run_continuous(&q, &cell, 2, 5, None, None, &reqs);

    pcdvq::paper::verify_codes_resident(&q).expect("codes stay resident under sharding");
    pcdvq::paper::verify_kv_cache_resident(&server).expect("sharded kv accounting verifies");

    let cache_per_node = server.kv_cache_bits_per_node().expect("sharded per-node cache bits");
    assert_eq!(cache_per_node.len(), n_nodes);
    assert_eq!(cache_per_node.iter().sum::<u64>(), server.kv_cache_bits(), "cache bits sum");
    assert!(cache_per_node.iter().all(|&b| b > 0), "every node holds cache state");

    let cb_per_node = server.kv_codebook_bits_per_node().expect("sharded per-node grids");
    assert_eq!(cb_per_node.len(), n_nodes);
    assert_eq!(cb_per_node.iter().sum::<u64>(), server.kv_codebook_bits(), "codebook bits sum");
    assert!(cb_per_node.iter().all(|&b| b > 0), "every node froze its own layers");

    // KV grids PARTITION across nodes (unlike weight codebooks, which are
    // resident once per node): the summed per-node grids equal a
    // single-node server's codec total for the same traffic.
    let single = Cell { shards: 1, kv_page: 4, kv_quant: 4 };
    let (_, host) = run_continuous(&q, &single, 2, 5, None, None, &reqs);
    assert_eq!(
        server.kv_codebook_bits(),
        host.kv_codebook_bits(),
        "sharded grids sum to the single-node codec"
    );
    assert!(host.kv_cache_bits_per_node().is_none(), "per-node bits are a sharded accessor");
}

/// Cross-request prefix sharing works on the sharded backend — node tries
/// publish and attach in lockstep, so coverage is topology-symmetric: hot
/// prompts reuse prefill, logical hit counters match the single-node run,
/// disabling the knob changes counters but never tokens, and every node's
/// page audit balances afterwards.
#[test]
fn sharded_prefix_sharing_is_topology_symmetric_and_leak_free() {
    let model = synthetic_model("prefix");
    let q = quantize(&model);

    let shared = prompt_bytes(24, 9);
    let reqs: Vec<(Vec<u8>, usize, f32)> = (0..3)
        .map(|i| {
            let mut p = shared.clone();
            p.extend(prompt_bytes(4, 40 + i));
            (p, 6, 0.0)
        })
        .collect();

    // max_slots = 1 serializes requests, so publication lands before the
    // next admission and the trie can actually hit
    let cell = Cell { shards: 2, kv_page: 4, kv_quant: 0 };
    let (r_share, s_share) = run_continuous(&q, &cell, 1, 8, None, Some(true), &reqs);
    let (r_plain, s_plain) = run_continuous(&q, &cell, 1, 8, None, Some(false), &reqs);
    let host = Cell { shards: 1, kv_page: 4, kv_quant: 0 };
    let (r_host, s_host) = run_continuous(&q, &host, 1, 8, None, Some(true), &reqs);

    for (i, ((a, b), c)) in r_share.iter().zip(&r_plain).zip(&r_host).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: sharing changed tokens");
        assert_eq!(a.generated, c.generated, "req {i}: sharded vs host prefix run");
    }
    assert!(s_share.metrics.prefix_tokens_reused > 0, "sharing never engaged");
    assert_eq!(s_plain.metrics.prefix_tokens_reused, 0, "disabled knob still reused");
    assert_eq!(s_share.metrics.prefix_hits, s_host.metrics.prefix_hits, "hit symmetry");
    assert_eq!(s_share.metrics.prefix_misses, s_host.metrics.prefix_misses, "miss symmetry");
    assert_eq!(
        s_share.metrics.prefix_tokens_reused, s_host.metrics.prefix_tokens_reused,
        "reuse symmetry"
    );

    for (n, audit) in s_share.kv_page_audit_per_node().expect("paged audit").iter().enumerate() {
        assert_eq!(audit.slot_chain_pages, 0, "node {n}: idle slots hold pages");
        assert_eq!(
            audit.created,
            audit.slot_free_pages + audit.prefix_pages + audit.dropped,
            "node {n}: page leak — audit was {audit:?}"
        );
    }
}

/// Property: `shard_layers` yields a deterministic, contiguous, disjoint
/// cover of `0..n_layer` that equals `exec::partition`, never emits an
/// empty range, and degrades to one-layer nodes when more shards are
/// requested than layers exist.
#[test]
fn prop_shard_layers_partition_contract() {
    for_cases(64, 0xA11C, |g| {
        let n_layer = g.usize_in(1, 12);
        let n_shards = g.usize_in(0, 16);
        let cfg =
            GptConfig { vocab: 256, d_model: 64, n_layer, n_head: 4, d_ff: 256, ctx: 64 };
        let ranges = shard_layers(&cfg, n_shards);
        assert_eq!(ranges, shard_layers(&cfg, n_shards), "case {}: deterministic", g.case_seed);
        assert_eq!(
            ranges,
            pcdvq::exec::partition(n_layer, n_shards.max(1)),
            "case {}: matches exec::partition",
            g.case_seed
        );
        assert_eq!(ranges[0].start, 0, "case {}: starts at layer 0", g.case_seed);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start, "case {}: contiguous + disjoint", g.case_seed);
        }
        assert_eq!(ranges.last().unwrap().end, n_layer, "case {}: covers", g.case_seed);
        assert!(ranges.iter().all(|r| !r.is_empty()), "case {}: no empty node", g.case_seed);
        assert!(ranges.len() <= n_layer.min(n_shards.max(1)), "case {}: width", g.case_seed);
        if n_shards > n_layer {
            assert_eq!(ranges.len(), n_layer, "case {}: one layer per node", g.case_seed);
        }
    });

    // degenerate geometry: a 0-layer config still yields a single empty plan
    let cfg0 = GptConfig { vocab: 256, d_model: 64, n_layer: 0, n_head: 4, d_ff: 256, ctx: 64 };
    assert_eq!(shard_layers(&cfg0, 3), vec![0..0]);
}

/// Property: interleaved multi-request traffic (random topology cell, slot
/// width, chunk size, request mix with sampled temperatures and
/// past-eviction lengths) through the sharded continuous loop is
/// per-request token-identical to the single-node host run, and every
/// node's page audit balances to zero leaks afterwards.
#[test]
fn prop_interleaved_sharded_serving_token_identical_and_leak_free() {
    let model = synthetic_model("prop_interleave");
    let ctx = model.config.ctx;
    let q = quantize(&model);

    for_cases(4, 0x5ADE, |g| {
        let shards = g.usize_in(2, 3);
        let kv_page = [0usize, 4][g.usize_in(0, 1)];
        let kv_quant = [0u32, 4][g.usize_in(0, 1)];
        let slots = g.usize_in(2, 3);
        let chunk = [1usize, 5, 16][g.usize_in(0, 2)];
        let n_req = g.usize_in(3, 6);
        let reqs: Vec<(Vec<u8>, usize, f32)> = (0..n_req)
            .map(|i| {
                let plen = g.usize_in(1, ctx + 6);
                let max_new = g.usize_in(1, 20);
                let temp = if g.usize_in(0, 1) == 1 { 0.7 } else { 0.0 };
                (prompt_bytes(plen, i), max_new, temp)
            })
            .collect();
        let tag = format!(
            "case {} (shards={shards} kv_page={kv_page} kv_quant={kv_quant} \
             slots={slots} chunk={chunk})",
            g.case_seed
        );

        let cell = Cell { shards, kv_page, kv_quant };
        let host = Cell { shards: 1, kv_page, kv_quant };
        let (rs, server) = run_continuous(&q, &cell, slots, chunk, None, None, &reqs);
        let (rh, _) = run_continuous(&q, &host, slots, chunk, None, None, &reqs);
        for (i, (a, b)) in rs.iter().zip(&rh).enumerate() {
            assert_eq!(a.generated, b.generated, "{tag}: req {i} diverged from host");
        }

        if kv_page > 0 {
            let audits = server.kv_page_audit_per_node().expect("paged sharded audit");
            assert_eq!(audits.len(), shard_layers(&model.config, shards).len(), "{tag}: nodes");
            for (n, audit) in audits.iter().enumerate() {
                assert_eq!(audit.slot_chain_pages, 0, "{tag}: node {n} idle slots hold pages");
                assert_eq!(
                    audit.created,
                    audit.slot_free_pages + audit.prefix_pages + audit.dropped,
                    "{tag}: node {n} page leak — audit was {audit:?}"
                );
            }
        } else {
            assert!(server.kv_page_audit_per_node().is_none(), "{tag}: dense cell has no audit");
        }
    });
}
