//! Coordinator integration: scheduler determinism under contention, batcher
//! + server against the real AOT artifacts, fwd_q ≡ fake-quant fwd_fp, and
//! the host **codes-resident** serving mode (which needs no artifacts at
//! all — packed codes + shared codebooks are the only resident weights).

use std::sync::mpsc::channel;
use std::time::Duration;

use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::config::{build_pcdvq_with, Paths};
use pcdvq::coordinator::{
    quantize_model_compressed, quantize_model_parallel, Batcher, BatcherConfig, GenRequest,
    Server, ServingWeights,
};
use pcdvq::model::{GptModel, QuantizedGpt};
use pcdvq::runtime::Engine;

fn artifacts_ready() -> Option<Paths> {
    let paths = Paths::detect();
    if paths.artifacts.join("fwd_q_gpt-mini.hlo.txt").exists() {
        Some(paths)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Synthetic model container (no build artifacts needed): d=64, 2 layers,
/// ctx 64 — the shared library fixture, written under the dir some tests
/// also reuse for their own artifacts.
fn synthetic_model(name: &str) -> GptModel {
    pcdvq::proptest::synthetic_tinygpt("pcdvq_coord_tests", name, 11)
}

/// A small PCDVQ (a=8) built directly — no artifact cache involvement.
fn small_pcdvq() -> pcdvq::quant::pcdvq::Pcdvq {
    pcdvq::proptest::tiny_pcdvq()
}

#[test]
fn host_codes_resident_server_serves_without_artifacts() {
    // The codes-resident mode is the whole point of the compressed-artifact
    // refactor: serving holds packed codes + shared codebooks only, and
    // needs neither XLA nor dense weights.
    let model = synthetic_model("host_serve");
    let pcdvq_q = small_pcdvq();
    let (q, stats) = quantize_model_compressed(&model, &pcdvq_q, 2);
    let payload = q.payload_bits();
    assert_eq!(stats.payload_bits, payload);
    // resident state ≈ payload (codebooks amortize), far below dense fp32
    pcdvq::paper::verify_codes_resident(&q).unwrap();
    assert!(q.resident_bits() * 8 < q.dense_bits());

    let mut server =
        Server::builder(ServingWeights::CodesResident(Box::new(q))).build().unwrap();
    assert!(server.is_codes_resident());
    assert_eq!(server.resident_weight_bits, payload);

    let (tx, rx) = channel::<GenRequest>();
    let mut batcher = Batcher::new(
        rx,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5), ..Default::default() },
    );
    let mut rxs = Vec::new();
    for i in 0..3 {
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(format!("hello {i}").into_bytes()).max_new(4).build(rtx))
            .unwrap();
        rxs.push(rrx);
    }
    drop(tx);
    server.serve(&mut batcher).unwrap();
    for rrx in rxs {
        let resp = rrx.recv().expect("response missing");
        assert_eq!(resp.generated.len(), 4);
    }
    assert_eq!(server.metrics.requests, 3);
}

#[test]
fn back_to_back_requests_match_fresh_servers() {
    // Per-request state is explicit: the slot's KV cache resets and the
    // sampling stream re-derives at every request boundary, so a server
    // that already served traffic answers exactly like a fresh one — for
    // greedy AND sampled decoding.
    let model = synthetic_model("back_to_back");
    let pcdvq_q = small_pcdvq();
    let (q, _) = quantize_model_compressed(&model, &pcdvq_q, 1);
    let mk = || {
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone()))).build().unwrap()
    };
    let run = |server: &mut Server, prompt: &[u8], temperature: f32| -> Vec<u8> {
        let (rtx, rrx) = channel();
        let req =
            GenRequest::builder(prompt.to_vec()).max_new(6).temperature(temperature).build(rtx);
        server.process_batch(vec![req]).unwrap();
        rrx.recv().unwrap().generated
    };
    for temperature in [0.0f32, 0.9] {
        let mut shared = mk();
        let a1 = run(&mut shared, b"first prompt", temperature);
        let a2 = run(&mut shared, b"and a second one", temperature);
        let b1 = run(&mut mk(), b"first prompt", temperature);
        let b2 = run(&mut mk(), b"and a second one", temperature);
        assert_eq!(a1, b1, "t={temperature}: request 1 leaked state");
        assert_eq!(a2, b2, "t={temperature}: request 2 leaked state");
    }
}

#[test]
fn empty_prompt_resolves_without_killing_the_batch() {
    // A degenerate request must not abort the batch or wedge other clients:
    // it resolves with zero tokens while its batchmates decode normally.
    let model = synthetic_model("empty_prompt");
    let (q, _) = quantize_model_compressed(&model, &small_pcdvq(), 1);
    let mut server =
        Server::builder(ServingWeights::CodesResident(Box::new(q))).build().unwrap();
    let (rtx1, rrx1) = channel();
    let (rtx2, rrx2) = channel();
    server
        .process_batch(vec![
            GenRequest::builder(Vec::new()).max_new(3).build(rtx1),
            GenRequest::builder(b"a real one".to_vec()).max_new(3).build(rtx2),
        ])
        .unwrap();
    assert_eq!(rrx1.recv().unwrap().generated.len(), 0);
    assert_eq!(rrx2.recv().unwrap().generated.len(), 3);
}

#[test]
fn cached_and_reforward_policies_agree_on_greedy() {
    // The KV-cached decode loop against its parity oracle, end to end
    // through the server (prompt + generation within ctx).
    use pcdvq::coordinator::DecodePolicy;
    let model = synthetic_model("policy_parity");
    let pcdvq_q = small_pcdvq();
    let (q, _) = quantize_model_compressed(&model, &pcdvq_q, 1);
    let gen = |decode: DecodePolicy| -> Vec<Vec<u8>> {
        let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .decode(decode)
            .build()
            .unwrap();
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut rxs = Vec::new();
        for i in 0..2 {
            let (rtx, rrx) = channel();
            tx.send(GenRequest::builder(format!("parity check {i}").into_bytes())
                .max_new(5)
                .build(rtx))
            .unwrap();
            rxs.push(rrx);
        }
        drop(tx);
        server.serve(&mut batcher).unwrap();
        assert_eq!(
            server.kv_cache_bits() > 0,
            decode == DecodePolicy::KvCached,
            "caches allocate only under the cached policy"
        );
        rxs.into_iter().map(|r| r.recv().unwrap().generated).collect()
    };
    assert_eq!(
        gen(DecodePolicy::KvCached),
        gen(DecodePolicy::Reforward),
        "cached decode diverged from the re-forward oracle"
    );
}

#[test]
fn host_codes_resident_matches_dense_host_serving() {
    // greedy decode from codes must equal greedy decode from the explicit
    // dequantized model (same tokens, end to end)
    let model = synthetic_model("host_parity");
    let pcdvq_q = small_pcdvq();
    let (q, _) = quantize_model_compressed(&model, &pcdvq_q, 1);
    let dense = q.to_dense();

    let gen = |weights: ServingWeights| -> Vec<u8> {
        let mut server = Server::builder(weights).build().unwrap();
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(b"the quantization".to_vec()).max_new(6).build(rtx))
            .unwrap();
        drop(tx);
        server.serve(&mut batcher).unwrap();
        rrx.recv().unwrap().generated
    };
    let from_codes = gen(ServingWeights::CodesResident(Box::new(q)));
    let from_dense = gen(ServingWeights::Fp(dense));
    assert_eq!(from_codes, from_dense, "codes-resident decode diverged");
}

#[test]
fn host_eval_runs_on_codes_resident_model() {
    // ppl + tasks through the ForwardPass trait on the host backend —
    // evaluation without artifacts and without dense weights
    let model = synthetic_model("host_eval");
    let pcdvq_q = small_pcdvq();
    let (q, _) = quantize_model_compressed(&model, &pcdvq_q, 1);
    let hf = pcdvq::model::HostForward::from_quantized(q).unwrap();
    assert!(hf.is_codes_resident());
    let ctx = model.config.ctx;
    let tokens: Vec<u32> = (0..2 * ctx + 1).map(|i| (i * 31 % 251) as u32).collect();
    let ppl = pcdvq::eval::evaluate_ppl(&hf, &model.config, &tokens, 2, 2, 1.0).unwrap();
    assert!(ppl.ppl.is_finite() && ppl.ppl > 1.0);
    assert_eq!(ppl.n_tokens, 2 * (ctx - 1));
}

#[test]
fn packed_persistence_round_trips_into_serving() {
    // quantize → save packed container → load → serve: the stored artifact
    // is the serving artifact
    let model = synthetic_model("host_io");
    let pcdvq_q = small_pcdvq();
    let (q, _) = quantize_model_compressed(&model, &pcdvq_q, 2);
    let dir = std::env::temp_dir().join("pcdvq_coord_tests");
    let path = dir.join("host_io_packed.pctq");
    pcdvq::io::save_quantized(&q, &path).unwrap();
    let loaded = pcdvq::io::load_quantized(&path, "host_io").unwrap();
    assert_eq!(loaded.payload_bits(), q.payload_bits());
    pcdvq::paper::verify_codes_resident(&loaded).unwrap();

    let gen = |qm: QuantizedGpt| -> Vec<u8> {
        let mut server =
            Server::builder(ServingWeights::CodesResident(Box::new(qm))).build().unwrap();
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(b"roundtrip".to_vec()).max_new(5).build(rtx)).unwrap();
        drop(tx);
        server.serve(&mut batcher).unwrap();
        rrx.recv().unwrap().generated
    };
    assert_eq!(gen(q), gen(loaded), "loaded artifact decodes differently");
}

#[test]
fn fwd_q_matches_fake_quant_fwd_fp() {
    // The serving artifact (in-graph dequant from codes) must produce the
    // same logits as running the dense fake-quant weights through fwd_fp —
    // the strongest cross-layer consistency check in the repo.
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let pcdvq = build_pcdvq_with(
        &paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )
    .unwrap();

    // path A: dense fake-quant through fwd_fp
    let (fake, _) = quantize_model_parallel(&model, &pcdvq, 2);
    let exe_fp = engine.load(paths.artifacts.join("fwd_fp_gpt-mini_b8")).unwrap();
    let fixed = pcdvq::eval::weight_inputs(&fake, &exe_fp.manifest).unwrap();
    let tokens: Vec<i32> = (0..8 * 128).map(|i| (i * 13 % 251) as i32).collect();
    let mut inputs = fixed;
    inputs.push(pcdvq::runtime::Input::I32(tokens.clone(), vec![8, 128]));
    let logits_fp = exe_fp.run_f32(&inputs).unwrap();

    // path B: codes through fwd_q
    let q = QuantizedGpt::quantize(&model, &pcdvq);
    let exe_q = engine.load(paths.artifacts.join("fwd_q_gpt-mini")).unwrap();
    let fixed_q =
        pcdvq::coordinator::server::quantized_inputs(&q, &pcdvq.dir, &pcdvq.mag, &exe_q.manifest)
            .unwrap();
    let mut inputs_q = fixed_q;
    inputs_q.push(pcdvq::runtime::Input::I32(tokens, vec![8, 128]));
    let logits_q = exe_q.run_f32(&inputs_q).unwrap();

    assert_eq!(logits_fp.len(), logits_q.len());
    let mut max_diff = 0.0f32;
    for (a, b) in logits_fp.iter().zip(&logits_q) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 5e-2, "fwd_q vs fake-quant fwd_fp max logit diff {max_diff}");
}

#[test]
fn scheduler_deterministic_under_contention() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let q = build_pcdvq_with(
        &paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        10,
        2,
        7,
    )
    .unwrap();
    let (a, sa) = quantize_model_parallel(&model, &q, 1);
    let (b, sb) = quantize_model_parallel(&model, &q, 4);
    for name in model.config.quantizable_names() {
        assert_eq!(
            a.tensors[&name].as_slice(),
            b.tensors[&name].as_slice(),
            "nondeterministic result for {name}"
        );
    }
    assert_eq!(sa.payload_bits, sb.payload_bits);
}

#[test]
fn server_round_trip_with_batcher() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let mut server =
        Server::new(&engine, &paths.artifacts, ServingWeights::Fp(model)).unwrap();

    let (tx, rx) = channel::<GenRequest>();
    let mut batcher = Batcher::new(
        rx,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5), ..Default::default() },
    );
    let mut rxs = Vec::new();
    for i in 0..5 {
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(format!("fn main{i}() {{").into_bytes())
            .max_new(6)
            .build(rtx))
        .unwrap();
        rxs.push(rrx);
    }
    drop(tx);
    server.serve(&mut batcher).unwrap();
    for rrx in rxs {
        let resp = rrx.recv().expect("response missing");
        assert_eq!(resp.generated.len(), 6);
    }
    assert_eq!(server.metrics.requests, 5);
    assert!(server.metrics.tokens_generated >= 30);
    // greedy decode of identical prompts must be deterministic across slots
}

#[test]
fn greedy_generation_deterministic() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut server = Server::new(
            &engine,
            &paths.artifacts,
            ServingWeights::Fp(model.clone()),
        )
        .unwrap();
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(b"the quantization".to_vec()).max_new(8).build(rtx))
            .unwrap();
        drop(tx);
        server.serve(&mut batcher).unwrap();
        outs.push(rrx.recv().unwrap().generated);
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be reproducible");
}
