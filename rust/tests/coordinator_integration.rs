//! Coordinator integration: scheduler determinism under contention, batcher
//! + server against the real AOT artifacts, fwd_q ≡ fake-quant fwd_fp.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::config::{build_pcdvq_with, Paths};
use pcdvq::coordinator::{
    quantize_model_parallel, Batcher, BatcherConfig, GenRequest, Server, ServingWeights,
};
use pcdvq::model::QuantizedGpt;
use pcdvq::runtime::Engine;

fn artifacts_ready() -> Option<Paths> {
    let paths = Paths::detect();
    if paths.artifacts.join("fwd_q_gpt-mini.hlo.txt").exists() {
        Some(paths)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn fwd_q_matches_fake_quant_fwd_fp() {
    // The serving artifact (in-graph dequant from codes) must produce the
    // same logits as running the dense fake-quant weights through fwd_fp —
    // the strongest cross-layer consistency check in the repo.
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let pcdvq = build_pcdvq_with(
        &paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )
    .unwrap();

    // path A: dense fake-quant through fwd_fp
    let (fake, _) = quantize_model_parallel(&model, &pcdvq, 2);
    let exe_fp = engine.load(paths.artifacts.join("fwd_fp_gpt-mini_b8")).unwrap();
    let fixed = pcdvq::eval::weight_inputs(&fake, &exe_fp.manifest).unwrap();
    let tokens: Vec<i32> = (0..8 * 128).map(|i| (i * 13 % 251) as i32).collect();
    let mut inputs = fixed;
    inputs.push(pcdvq::runtime::Input::I32(tokens.clone(), vec![8, 128]));
    let logits_fp = exe_fp.run_f32(&inputs).unwrap();

    // path B: codes through fwd_q
    let q = QuantizedGpt::quantize(&model, &pcdvq);
    let exe_q = engine.load(paths.artifacts.join("fwd_q_gpt-mini")).unwrap();
    let fixed_q =
        pcdvq::coordinator::server::quantized_inputs(&q, &pcdvq.dir, &pcdvq.mag, &exe_q.manifest)
            .unwrap();
    let mut inputs_q = fixed_q;
    inputs_q.push(pcdvq::runtime::Input::I32(tokens, vec![8, 128]));
    let logits_q = exe_q.run_f32(&inputs_q).unwrap();

    assert_eq!(logits_fp.len(), logits_q.len());
    let mut max_diff = 0.0f32;
    for (a, b) in logits_fp.iter().zip(&logits_q) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 5e-2, "fwd_q vs fake-quant fwd_fp max logit diff {max_diff}");
}

#[test]
fn scheduler_deterministic_under_contention() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let q = build_pcdvq_with(
        &paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        10,
        2,
        7,
    )
    .unwrap();
    let (a, sa) = quantize_model_parallel(&model, &q, 1);
    let (b, sb) = quantize_model_parallel(&model, &q, 4);
    for name in model.config.quantizable_names() {
        assert_eq!(
            a.tensors[&name].as_slice(),
            b.tensors[&name].as_slice(),
            "nondeterministic result for {name}"
        );
    }
    assert_eq!(sa.payload_bits, sb.payload_bits);
}

#[test]
fn server_round_trip_with_batcher() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let mut server =
        Server::new(&engine, &paths.artifacts, ServingWeights::Fp(model)).unwrap();

    let (tx, rx) = channel::<GenRequest>();
    let batcher = Batcher::new(
        rx,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
    );
    let mut rxs = Vec::new();
    for i in 0..5 {
        let (rtx, rrx) = channel();
        tx.send(GenRequest {
            prompt: format!("fn main{i}() {{").into_bytes(),
            max_new: 6,
            temperature: 0.0,
            resp: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        rxs.push(rrx);
    }
    drop(tx);
    server.serve(&batcher).unwrap();
    for rrx in rxs {
        let resp = rrx.recv().expect("response missing");
        assert_eq!(resp.generated.len(), 6);
    }
    assert_eq!(server.metrics.requests, 5);
    assert!(server.metrics.tokens_generated >= 30);
    // greedy decode of identical prompts must be deterministic across slots
}

#[test]
fn greedy_generation_deterministic() {
    let Some(paths) = artifacts_ready() else { return };
    let model = paths.load_model("gpt-mini").unwrap();
    let engine = Engine::new().unwrap();
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut server = Server::new(
            &engine,
            &paths.artifacts,
            ServingWeights::Fp(model.clone()),
        )
        .unwrap();
        let (tx, rx) = channel::<GenRequest>();
        let batcher = Batcher::new(rx, BatcherConfig::default());
        let (rtx, rrx) = channel();
        tx.send(GenRequest {
            prompt: b"the quantization".to_vec(),
            max_new: 8,
            temperature: 0.0,
            resp: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        drop(tx);
        server.serve(&batcher).unwrap();
        outs.push(rrx.recv().unwrap().generated);
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be reproducible");
}
