//! End-to-end ingress tests over a real socket (DESIGN.md §14).
//!
//! The contract under test: HTTP is a *transparent* transport — an SSE
//! stream carries exactly the tokens the in-process `serve_continuous`
//! path produces for the same request and seed; the admission gate sheds
//! overload early with 429 (+ Retry-After) so admitted requests never time
//! out late; tenant fairness (weighted round-robin in the batcher) is
//! observable from the outside; and `GET /metrics` is valid Prometheus
//! text whose counters only ever go up.
//!
//! Timing discipline: tests that need a busy server park a long occupier
//! request in the (single) slot and use the gate's own counters to wait
//! for admission — no bare sleeps deciding correctness. The occupier's
//! generation (thousands of tokens) dwarfs the microseconds-to-millis the
//! asserting requests take, which is what makes the shed/fairness
//! assertions robust.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use pcdvq::coordinator::ingress::{http_request, parse_sse, post_generate, sse_tokens};
use pcdvq::coordinator::{
    Batcher, BatcherConfig, FinishReason, GenRequest, Ingress, IngressConfig, Server,
    ServingWeights,
};
use pcdvq::model::QuantizedGpt;
use pcdvq::proptest::{synthetic_tinygpt, tiny_pcdvq};

fn quantized() -> QuantizedGpt {
    let model = synthetic_tinygpt("pcdvq_ingress_tests", "ingress", 23);
    QuantizedGpt::quantize(&model, &tiny_pcdvq())
}

fn mk_server(q: &QuantizedGpt, max_slots: usize) -> Server {
    Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(max_slots)
        .prefill_chunk(16)
        .build()
        .unwrap()
}

/// Block until `tenant` has at least `n` admitted requests at the gate, or
/// panic after 10s — the no-bare-sleeps way to sequence traffic phases.
fn wait_admitted(ingress: &Ingress, tenant: &str, n: u64) {
    let t0 = Instant::now();
    while ingress.tenant_counters(tenant).0 < n {
        assert!(t0.elapsed() < Duration::from_secs(10), "tenant {tenant} never reached {n}");
        std::thread::yield_now();
    }
}

/// The SSE stream is token-identical to the in-process path: same prompt,
/// same admission seq (0), same server seed — greedy and sampled.
#[test]
fn sse_stream_matches_in_process_serving() {
    let q = quantized();
    for temperature in [0.0f32, 0.9] {
        // in-process reference (admission seq 0, like the first HTTP req)
        let mut server = mk_server(&q, 2);
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (rtx, rrx) = channel();
        batcher.push(
            GenRequest::builder(b"the polar quantizer".to_vec())
                .max_new(12)
                .temperature(temperature)
                .build(rtx),
        );
        server.serve_continuous(&mut batcher).unwrap();
        let reference = rrx.recv().unwrap();
        assert_eq!(reference.generated.len(), 12);

        // the same request over the wire
        let ingress = Ingress::spawn(
            mk_server(&q, 2),
            BatcherConfig::default(),
            IngressConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let resp =
            post_generate(ingress.addr(), "the polar quantizer", 12, temperature, "", 0).unwrap();
        assert_eq!(resp.status, 200, "t={temperature}: body {}", resp.body);
        assert_eq!(resp.header("content-type"), Some("text/event-stream"));
        let events = parse_sse(&resp.body);
        assert_eq!(
            sse_tokens(&events),
            reference.generated,
            "t={temperature}: SSE tokens diverged from the in-process path"
        );
        let usage = events.last().unwrap();
        assert_eq!(usage.event, "usage");
        assert!(usage.data.contains("\"tokens\":12"), "usage: {}", usage.data);
        assert!(usage.data.contains("\"seq\":0"), "usage: {}", usage.data);
        assert!(usage.data.contains("\"finish\":\"done\""), "usage: {}", usage.data);

        let server = ingress.shutdown().unwrap();
        assert_eq!(server.metrics.requests, 1);
        assert_eq!(server.metrics.tokens_generated, 12);
        assert_eq!(reference.finish, FinishReason::Done);
    }
}

/// Synthetic overload: one occupier pins the single slot and the only
/// in-flight budget; a concurrent flood then sheds with 429 + Retry-After
/// *before* queueing — and nothing admitted ever times out, even though
/// every request carries a deadline.
#[test]
fn overload_sheds_early_with_429_and_no_late_timeouts() {
    let q = quantized();
    let cfg = IngressConfig { max_in_flight: 1, ..IngressConfig::default() };
    let ingress =
        Ingress::spawn(mk_server(&q, 1), BatcherConfig::default(), cfg, "127.0.0.1:0").unwrap();
    let addr = ingress.addr();

    // the occupier: thousands of tokens through the only slot
    let occupier = std::thread::spawn(move || {
        post_generate(addr, "hold the slot", 4000, 0.0, "occ", 60_000).unwrap()
    });
    wait_admitted(&ingress, "occ", 1);

    // concurrent flood while the occupier owns the whole in-flight budget
    let flood: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                post_generate(addr, &format!("flood {i}"), 1, 0.0, "flood", 30_000).unwrap()
            })
        })
        .collect();
    let results: Vec<_> = flood.into_iter().map(|h| h.join().unwrap()).collect();
    let sheds: Vec<_> = results.iter().filter(|r| r.status == 429).collect();
    let done = results.iter().filter(|r| r.status == 200).count();
    assert!(
        sheds.len() >= 4,
        "expected most of the flood shed, got {} of 6 (statuses: {:?})",
        sheds.len(),
        results.iter().map(|r| r.status).collect::<Vec<_>>()
    );
    assert_eq!(done + sheds.len(), 6, "flood outcomes must be 200 or 429");
    for r in &sheds {
        let retry: u64 = r
            .header("retry-after")
            .expect("429 carries Retry-After")
            .parse()
            .expect("Retry-After is integral seconds");
        assert!(retry >= 1);
        assert!(r.body.contains("\"error\":\"shed\""), "shed body: {}", r.body);
    }
    // any flood request that did get through finished cleanly
    for r in results.iter().filter(|r| r.status == 200) {
        assert!(r.body.contains("\"finish\":\"done\""), "admitted body: {}", r.body);
    }

    let occ = occupier.join().unwrap();
    assert_eq!(occ.status, 200);
    assert_eq!(sse_tokens(&parse_sse(&occ.body)).len(), 4000);

    let (occ_admitted, occ_shed) = ingress.tenant_counters("occ");
    let (_, flood_shed) = ingress.tenant_counters("flood");
    assert_eq!((occ_admitted, occ_shed), (1, 0));
    assert_eq!(flood_shed, sheds.len() as u64);

    let server = ingress.shutdown().unwrap();
    assert_eq!(server.metrics.timeouts, 0, "shedding must preempt deadline timeouts");
    assert_eq!(server.metrics.shed, 0, "gate sheds never reached the batcher");
    assert_eq!(server.metrics.requests, 1 + done as u64);
}

/// Two-tenant skewed load: tenant `a` floods 8 requests first, tenant `b`
/// adds 2 afterwards — weighted round-robin in the batcher interleaves
/// them, so `b` finishes long before `a`'s backlog drains (plain FIFO
/// would leave `b` last).
#[test]
fn late_minority_tenant_is_not_starved_by_an_early_flood() {
    let q = quantized();
    let ingress = Ingress::spawn(
        mk_server(&q, 1),
        BatcherConfig::default(),
        IngressConfig::default(),
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = ingress.addr();

    // pin the slot so both tenants' queues build up behind it
    let occupier = std::thread::spawn(move || {
        post_generate(addr, "hold the slot", 8000, 0.0, "occ", 0).unwrap()
    });
    wait_admitted(&ingress, "occ", 1);

    let clock = Instant::now();
    let spawn_tenant = |tenant: &'static str, i: usize| {
        std::thread::spawn(move || {
            let r = post_generate(addr, &format!("{tenant} req {i}"), 30, 0.0, tenant, 0).unwrap();
            assert_eq!(r.status, 200, "{tenant} {i}: {}", r.body);
            clock.elapsed()
        })
    };
    let a_threads: Vec<_> = (0..8).map(|i| spawn_tenant("a", i)).collect();
    wait_admitted(&ingress, "a", 8);
    // small grace so the admitted requests are routed into the batcher's
    // tenant queues before b arrives (admission happens just before send)
    std::thread::sleep(Duration::from_millis(30));
    let b_threads: Vec<_> = (0..2).map(|i| spawn_tenant("b", i)).collect();

    let a_done: Vec<Duration> = a_threads.into_iter().map(|h| h.join().unwrap()).collect();
    let b_done: Vec<Duration> = b_threads.into_iter().map(|h| h.join().unwrap()).collect();
    let occ = occupier.join().unwrap();
    assert_eq!(occ.status, 200);

    let last_a = a_done.iter().max().unwrap();
    let last_b = b_done.iter().max().unwrap();
    assert!(
        last_b < last_a,
        "tenant b (late, 2 reqs) finished after tenant a's 8-deep backlog \
         (b last {last_b:?}, a last {last_a:?}) — round-robin fairness broken"
    );

    let server = ingress.shutdown().unwrap();
    assert_eq!(server.metrics.requests, 11);
    assert_eq!(server.metrics.timeouts, 0);
}

/// Parse a Prometheus text body: every non-comment line is
/// `name[{labels}] value`; returns the samples. Panics on malformed lines.
fn parse_prometheus(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in: {line}"));
        let metric = name.split('{').next().unwrap();
        assert!(
            !metric.is_empty()
                && metric.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name: {line}"
        );
        if name.contains('{') {
            assert!(name.ends_with('}'), "unterminated labels: {line}");
        }
        out.push((name.to_string(), v));
    }
    out
}

/// `GET /metrics` is valid Prometheus text before and after traffic, and
/// every `*_total` counter is monotone across scrapes. `GET /healthz`
/// answers; unknown routes 404.
#[test]
fn metrics_endpoint_is_valid_prometheus_and_counters_are_monotone() {
    let q = quantized();
    let ingress = Ingress::spawn(
        mk_server(&q, 2),
        BatcherConfig::default(),
        IngressConfig::default(),
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = ingress.addr();

    let health = http_request(addr, "GET", "/healthz", None).unwrap();
    assert_eq!((health.status, health.body.as_str()), (200, "ok\n"));
    assert_eq!(http_request(addr, "GET", "/nope", None).unwrap().status, 404);

    let scrape = |min_requests: f64| -> Vec<(String, f64)> {
        // the serving thread publishes its mirror just after responding, so
        // poll (bounded) instead of racing it
        let t0 = Instant::now();
        loop {
            let r = http_request(addr, "GET", "/metrics", None).unwrap();
            assert_eq!(r.status, 200);
            assert_eq!(
                r.header("content-type"),
                Some("text/plain; version=0.0.4; charset=utf-8")
            );
            let samples = parse_prometheus(&r.body);
            let requests = samples
                .iter()
                .find(|(n, _)| n == "pallas_requests_total")
                .map(|(_, v)| *v)
                .expect("pallas_requests_total missing");
            if requests >= min_requests {
                return samples;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "mirror never caught up");
            std::thread::yield_now();
        }
    };

    let before = scrape(0.0);
    for name in [
        "pallas_requests_total",
        "pallas_tokens_generated_total",
        "pallas_timeouts_total",
        "pallas_shed_total",
        "pallas_slot_occupancy",
        "pallas_ingress_in_flight",
    ] {
        assert!(before.iter().any(|(n, _)| n == name), "{name} missing from /metrics");
    }
    // quantile families carry labels
    assert!(before.iter().any(|(n, _)| n == "pallas_ttft_ms{quantile=\"0.5\"}"));
    assert!(before.iter().any(|(n, _)| n == "pallas_queue_wait_ms{quantile=\"0.99\"}"));

    for i in 0..3 {
        let r = post_generate(addr, &format!("traffic {i}"), 4, 0.0, "scraper", 0).unwrap();
        assert_eq!(r.status, 200);
    }
    let after = scrape(3.0);
    assert!(after
        .iter()
        .any(|(n, v)| n == "pallas_tenant_admitted_total{tenant=\"scraper\"}" && *v == 3.0));

    for (name, v0) in before.iter().filter(|(n, _)| n.contains("_total")) {
        let v1 = after
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("{name} vanished between scrapes"))
            .1;
        assert!(v1 >= *v0, "counter {name} went backwards: {v0} -> {v1}");
    }
    let toks = |s: &[(String, f64)]| {
        s.iter().find(|(n, _)| n == "pallas_tokens_generated_total").unwrap().1
    };
    assert_eq!(toks(&after) - toks(&before), 12.0, "3 requests x 4 tokens");

    ingress.shutdown().unwrap();
}
