//! Continuous batching + block prefill vs the decode oracles.
//!
//! The contract (DESIGN.md §9): per-request outputs under
//! `Server::serve_continuous` equal single-request oracle runs
//! token-for-token (greedy) — `DecodePolicy::Reforward` while the window
//! fits in ctx, the static KV-cached path across the eviction boundary —
//! and the per-step logits match the re-forward oracle within 1e-5,
//! regardless of slot count, prefill chunk size, or traffic interleaving.
//! Plus: `prefill_block` leaves the cache **byte-identical** to
//! token-at-a-time `prefill` for every chunk size (including across the
//! slide+rebuild eviction boundary), admission is FIFO and starvation-free,
//! and deadlines resolve as timeouts instead of occupying slots.
//!
//! Since PR 5 the per-slot steps fan out on the shared worker pool
//! (`Server::threads`, DESIGN.md §12): the whole suite runs under whatever
//! `PALLAS_THREADS` CI sets (named steps pin 1 and 4), and
//! `parallel_slot_pool_matches_serial_outputs_and_metrics` additionally
//! compares explicit 1- vs 4-thread runs token-for-token and
//! counter-for-counter.

use std::sync::mpsc::channel;
use std::time::Instant;

use pcdvq::coordinator::{
    Batcher, BatcherConfig, DecodePolicy, FinishReason, GenRequest, GenResponse, Priority, Server,
    ServingWeights,
};
use pcdvq::model::{GptModel, HostForward, KvCache, QuantizedGpt};
use pcdvq::proptest::{for_cases, synthetic_tinygpt, tiny_pcdvq};

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the continuous-batching
/// testbed.
fn synthetic_model(name: &str) -> GptModel {
    synthetic_tinygpt("pcdvq_continuous_tests", name, 31)
}

fn quantize(model: &GptModel) -> QuantizedGpt {
    QuantizedGpt::quantize(model, &tiny_pcdvq())
}

fn prompt_bytes(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 7 + salt * 13 + 5) % 251) as u8).collect()
}

/// Serve `reqs` = (prompt, max_new, temperature) through the continuous
/// loop — all requests pre-queued (deterministic admission, no sleeping).
fn run_continuous(
    q: &QuantizedGpt,
    max_slots: usize,
    prefill_chunk: usize,
    capture_logits: bool,
    reqs: &[(Vec<u8>, usize, f32)],
) -> (Vec<GenResponse>, Server) {
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(max_slots)
        .prefill_chunk(prefill_chunk)
        .capture_logits(capture_logits)
        .build()
        .unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rxs = Vec::new();
    for (p, max_new, temp) in reqs {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
        rxs.push(rrx);
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps = rxs.iter().map(|r| r.recv().expect("response missing")).collect();
    (resps, server)
}

/// Single-request oracle run through the server under `policy`.
fn run_single(
    q: &QuantizedGpt,
    policy: DecodePolicy,
    prompt: &[u8],
    max_new: usize,
) -> Vec<u8> {
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .decode(policy)
        .build()
        .unwrap();
    let (rtx, rrx) = channel();
    server
        .process_batch(vec![GenRequest::builder(prompt.to_vec()).max_new(max_new).build(rtx)])
        .unwrap();
    rrx.recv().unwrap().generated
}

/// The windowed re-forward oracle with per-step logits: greedy decode where
/// every token re-forwards the whole window (slide-by-one past ctx),
/// exactly the `DecodePolicy::Reforward` schedule.
fn oracle_reforward(
    hf: &HostForward,
    prompt: &[u8],
    max_new: usize,
) -> (Vec<u8>, Vec<Vec<f32>>) {
    let ctx = hf.config.ctx;
    let v = hf.config.vocab;
    let mut buf: Vec<i32> = prompt
        .iter()
        .rev()
        .take(ctx - 1)
        .rev()
        .map(|&x| x as i32)
        .collect();
    assert!(!buf.is_empty(), "oracle needs a non-empty prompt");
    let mut toks = Vec::new();
    let mut logits_seq = Vec::new();
    for _ in 0..max_new {
        let start = buf.len().saturating_sub(ctx);
        let window = buf[start..].to_vec();
        let t = window.len();
        let logits = hf.forward(&window, 1, t).unwrap();
        let row = logits[(t - 1) * v..t * v].to_vec();
        let next = pcdvq::tensor::argmax(&row) as u8;
        toks.push(next);
        buf.push(next as i32);
        logits_seq.push(row);
    }
    (toks, logits_seq)
}

/// The headline equivalence matrix: mixed-length request sets through 3
/// slots at ragged and aligned chunk sizes — every request's greedy tokens
/// equal its single-request `Reforward` oracle run token-for-token, and the
/// captured per-step logits match within 1e-5. Covers prompts of length 1,
/// below/at/above ctx (prompt > ctx truncates to the last ctx−1 bytes in
/// both paths).
#[test]
fn continuous_matches_single_request_reforward_oracle() {
    let model = synthetic_model("oracle");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    let hf = HostForward::from_quantized(q.clone()).unwrap();

    // (prompt_len, max_new) with trunc_len + max_new ≤ ctx + 1 so the
    // cached and re-forward window schedules coincide (DESIGN.md §9)
    let cases: Vec<(usize, usize)> = vec![
        (1, 6),
        (5, 6),
        (ctx / 2 - 1, 6),
        (ctx - 1, 2),
        (ctx, 2),
        (ctx + 9, 2),
    ];
    let reqs: Vec<(Vec<u8>, usize, f32)> = cases
        .iter()
        .enumerate()
        .map(|(i, &(plen, max_new))| (prompt_bytes(plen, i), max_new, 0.0))
        .collect();

    for chunk in [1usize, 5, ctx / 4] {
        let (resps, server) = run_continuous(&q, 3, chunk, true, &reqs);
        assert_eq!(server.metrics.requests as usize, reqs.len());
        for (i, (resp, (prompt, max_new, _))) in resps.iter().zip(&reqs).enumerate() {
            let via_server = run_single(&q, DecodePolicy::Reforward, prompt, *max_new);
            let (oracle_toks, oracle_logits) = oracle_reforward(&hf, prompt, *max_new);
            assert_eq!(via_server, oracle_toks, "req {i}: oracle self-check");
            assert_eq!(
                resp.generated, oracle_toks,
                "req {i} (chunk {chunk}): continuous diverged from re-forward oracle"
            );
            assert_eq!(resp.logits.len(), *max_new, "req {i}: captured logits");
            for (step, (got, want)) in resp.logits.iter().zip(&oracle_logits).enumerate() {
                for (j, (a, b)) in got.iter().zip(want).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-5,
                        "req {i} step {step} logit {j}: continuous {a} vs oracle {b}"
                    );
                }
            }
            assert!(resp.ttft.is_some(), "req {i}: first token timed");
            assert_eq!(resp.seq, i as u64, "admission follows arrival order");
        }
    }
}

/// Admission mid-decode + slot reuse: with 2 slots, a long request keeps
/// decoding while its batchmates finish and their slot turns over to queued
/// requests — every output still equals its solo oracle run.
#[test]
fn admission_mid_decode_and_slot_reuse_preserve_outputs() {
    let model = synthetic_model("mid_decode");
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (prompt_bytes(20, 0), 12, 0.0), // long: holds slot 0 throughout
        (prompt_bytes(9, 1), 2, 0.0),
        (prompt_bytes(11, 2), 2, 0.0), // admitted mid-decode of the long one
        (prompt_bytes(7, 3), 2, 0.0),  // reuses the freed slot again
    ];
    let (resps, server) = run_continuous(&q, 2, 4, false, &reqs);
    for (i, (resp, (prompt, max_new, _))) in resps.iter().zip(&reqs).enumerate() {
        let solo = run_single(&q, DecodePolicy::KvCached, prompt, *max_new);
        assert_eq!(resp.generated, solo, "req {i}: interleaving changed the output");
        assert_eq!(resp.seq, i as u64, "req {i}: FIFO admission");
    }
    // the short requests rode the second slot while the long one decoded:
    // they must all complete strictly before it
    for short in &resps[1..] {
        assert!(
            short.latency < resps[0].latency,
            "short request waited for the long one (no continuous admission?)"
        );
    }
    assert_eq!(server.metrics.requests, 4);
    assert!(server.metrics.slot_occupancy() > 0.5, "pool mostly busy");
}

/// Past the eviction boundary the cached slide policy takes over (stride
/// ctx/4, not the re-forward's slide-by-one): continuous outputs must equal
/// the static KV-cached path token-for-token there — same caches, same
/// schedule, different serving loop.
#[test]
fn prompt_past_ctx_matches_static_cached_path() {
    let model = synthetic_model("past_ctx");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (prompt_bytes(ctx + 9, 0), 8, 0.0),     // evicts during generation
        (prompt_bytes(2 * ctx, 1), 6, 0.0),     // heavy truncation first
        (prompt_bytes(ctx - 1, 2), ctx / 2, 0.0), // long generation run
    ];
    for chunk in [1usize, ctx / 4, ctx + 5] {
        let (resps, _) = run_continuous(&q, 2, chunk, false, &reqs);
        for (i, (resp, (prompt, max_new, _))) in resps.iter().zip(&reqs).enumerate() {
            let solo = run_single(&q, DecodePolicy::KvCached, prompt, *max_new);
            assert_eq!(
                resp.generated, solo,
                "req {i} (chunk {chunk}): eviction schedule diverged"
            );
        }
    }
}

/// Sampling streams derive from the admission seq, not the slot index:
/// the same sampled traffic produces identical outputs whether it shares
/// one slot or spreads over three.
#[test]
fn sampled_outputs_independent_of_slot_placement() {
    let model = synthetic_model("sampled");
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = (0..4)
        .map(|i| (prompt_bytes(10 + i, i), 5, 0.9))
        .collect();
    let (one_slot, _) = run_continuous(&q, 1, 8, false, &reqs);
    let (three_slots, _) = run_continuous(&q, 3, 8, false, &reqs);
    for (i, (a, b)) in one_slot.iter().zip(&three_slots).enumerate() {
        assert_eq!(
            a.generated, b.generated,
            "req {i}: sampled stream depended on slot placement"
        );
    }
}

/// Property (satellite): `prefill_block(chunk=k)` leaves the cache
/// **byte-identical** to token-at-a-time `prefill` — tokens, K/V rows,
/// telemetry counters, and the final logits — for k in
/// {1, 3, ctx/4, ctx, ctx+5}, across random prompt lengths including the
/// slide+rebuild eviction boundary.
#[test]
fn prop_prefill_block_byte_identical_to_token_at_a_time() {
    let model = synthetic_model("prop_block");
    let ctx = model.config.ctx;
    let hf = HostForward::from_dense(model.clone()).unwrap();
    for_cases(5, 0xB10C, |g| {
        let n = g.usize_in(1, ctx + 20);
        let stream: Vec<i32> = (0..n).map(|_| g.rng.below(251) as i32).collect();
        let mut ref_cache = KvCache::new(&model.config);
        let ref_logits = hf.prefill(&stream, &mut ref_cache).unwrap();
        for k in [1usize, 3, ctx / 4, ctx, ctx + 5] {
            let mut cache = KvCache::new(&model.config);
            let logits = hf.prefill_block(&stream, &mut cache, k).unwrap();
            let tag = format!("case {} chunk {k} len {n}", g.case_seed);
            assert_eq!(cache.tokens(), ref_cache.tokens(), "{tag}: token window");
            assert_eq!(cache.len(), ref_cache.len(), "{tag}: len");
            assert_eq!(cache.total_fed(), ref_cache.total_fed(), "{tag}: total_fed");
            assert_eq!(cache.evictions(), ref_cache.evictions(), "{tag}: evictions");
            for layer in 0..model.config.n_layer {
                let (ka, va) = ref_cache.layer(layer);
                let (kb, vb) = cache.layer(layer);
                for i in 0..ref_cache.len() {
                    assert_eq!(ka.row(i), kb.row(i), "{tag}: K layer {layer} row {i}");
                    assert_eq!(va.row(i), vb.row(i), "{tag}: V layer {layer} row {i}");
                }
            }
            assert_eq!(logits, ref_logits, "{tag}: logits");
        }
    });
}

/// The eviction boundary, explicitly, on the codes-resident backend: the
/// whole byte-identity property holds when the matmuls run from packed
/// codes too.
#[test]
fn prefill_block_byte_identical_across_eviction_codes_resident() {
    let model = synthetic_model("codes_block");
    let ctx = model.config.ctx;
    let hf = HostForward::from_quantized(quantize(&model)).unwrap();
    let stream: Vec<i32> = (0..ctx + 5).map(|i| ((i * 37 + 3) % 251) as i32).collect();
    let mut ref_cache = KvCache::new(&model.config);
    let ref_logits = hf.prefill(&stream, &mut ref_cache).unwrap();
    assert!(ref_cache.evictions() >= 1, "stream must cross the boundary");
    for k in [3usize, ctx / 4, ctx + 5] {
        let mut cache = KvCache::new(&model.config);
        let logits = hf.prefill_block(&stream, &mut cache, k).unwrap();
        assert_eq!(cache.tokens(), ref_cache.tokens(), "chunk {k}");
        assert_eq!(cache.evictions(), ref_cache.evictions(), "chunk {k}");
        assert_eq!(logits, ref_logits, "chunk {k}");
    }
}

/// Fairness/starvation regression: with 2 slots and one long-running
/// request, later short requests still complete (strictly before the long
/// one), admission stays FIFO, and queue waits are monotone in arrival
/// order. Enqueue times are pinned to one instant — the injectable-clock
/// trick that makes the wait ordering deterministic without sleeping.
#[test]
fn short_requests_never_starve_behind_a_long_one() {
    let model = synthetic_model("fairness");
    let q = quantize(&model);
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(2)
        .prefill_chunk(16)
        .build()
        .unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut push = |prompt: Vec<u8>, max_new: usize| {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest {
            prompt,
            max_new,
            temperature: 0.0,
            resp: rtx,
            enqueued: t0, // pinned: queue waits comparable across requests
            deadline: None,
            tenant: String::new(),
            priority: Priority::Normal,
            stream: None,
        });
        rxs.push(rrx);
    };
    push(prompt_bytes(12, 0), 40); // the long-running request
    for i in 1..=4 {
        push(prompt_bytes(8, i), 2); // later, short requests
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps: Vec<GenResponse> = rxs.iter().map(|r| r.recv().unwrap()).collect();

    assert_eq!(resps[0].generated.len(), 40);
    for (i, short) in resps[1..].iter().enumerate() {
        assert_eq!(short.generated.len(), 2, "short {i} completed fully");
        assert!(
            short.latency < resps[0].latency,
            "short {i} starved behind the long request"
        );
        // a short request consumes only its own steps (1 prefill chunk that
        // emits the first token + 1 decode step), not the long one's 40
        assert!(short.steps <= 3, "short {i} took {} steps", short.steps);
    }
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.seq, i as u64, "admission order == arrival order");
    }
    let waits = server.metrics.queue_waits_us();
    assert_eq!(waits.len(), 5);
    for w in waits.windows(2) {
        assert!(w[1] >= w[0], "queue waits not monotone in arrival order: {waits:?}");
    }
    assert_eq!(server.metrics.timeouts, 0);
}

/// A request whose deadline expired before a slot freed resolves as
/// [`FinishReason::TimedOut`] without occupying the pool; its batchmates
/// are unaffected.
#[test]
fn expired_deadline_times_out_in_the_serving_loop() {
    let model = synthetic_model("deadline");
    let q = quantize(&model);
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(1)
        .build()
        .unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let (rtx1, rrx1) = channel();
    batcher.push(GenRequest::builder(prompt_bytes(6, 0)).max_new(3).build(rtx1));
    let (rtx2, rrx2) = channel();
    let mut expired = GenRequest::builder(prompt_bytes(6, 1)).max_new(3).build(rtx2);
    expired.deadline = Some(expired.enqueued); // already past
    batcher.push(expired);
    let (rtx3, rrx3) = channel();
    batcher.push(GenRequest::builder(prompt_bytes(6, 2)).max_new(3).build(rtx3));
    server.serve_continuous(&mut batcher).unwrap();

    assert_eq!(rrx1.recv().unwrap().generated.len(), 3);
    let dead = rrx2.recv().unwrap();
    assert_eq!(dead.finish, FinishReason::TimedOut);
    assert!(dead.generated.is_empty());
    let live = rrx3.recv().unwrap();
    assert_eq!(live.finish, FinishReason::Done);
    assert_eq!(live.generated.len(), 3);
    assert_eq!(server.metrics.timeouts, 1);
    assert_eq!(server.metrics.requests, 2, "timed-out request never held a slot");
}

/// The parallel slot pool (exec-driven fan-out of the per-slot steps) is
/// output- and metrics-invariant: the same traffic served with 1 and 4
/// worker threads produces identical tokens, admission seqs, and scheduler
/// counters — the DESIGN.md §12 determinism contract, end to end.
#[test]
fn parallel_slot_pool_matches_serial_outputs_and_metrics() {
    let model = synthetic_model("pool");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (prompt_bytes(9, 0), 6, 0.0),
        (prompt_bytes(ctx - 1, 1), 3, 0.9), // sampled, long prompt
        (prompt_bytes(4, 2), 5, 0.0),
        (prompt_bytes(ctx + 7, 3), 4, 0.0), // truncates + evicts
        (prompt_bytes(13, 4), 2, 0.7),
        (Vec::new(), 3, 0.0), // degenerate rides along
    ];
    let run = |threads: usize| {
        let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(3)
            .prefill_chunk(8)
            .threads(threads)
            .build()
            .unwrap();
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut rxs = Vec::new();
        for (p, max_new, temp) in &reqs {
            let (rtx, rrx) = channel();
            batcher
                .push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
            rxs.push(rrx);
        }
        server.serve_continuous(&mut batcher).unwrap();
        let resps: Vec<GenResponse> = rxs.iter().map(|r| r.recv().unwrap()).collect();
        (resps, server)
    };
    let (serial, serial_srv) = run(1);
    for threads in [2usize, 4] {
        let (par, par_srv) = run(threads);
        for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
            assert_eq!(a.generated, b.generated, "req {i}: threads={threads} output");
            assert_eq!(a.seq, b.seq, "req {i}: admission order");
            assert_eq!(a.steps, b.steps, "req {i}: scheduler steps");
        }
        assert_eq!(par_srv.metrics.requests, serial_srv.metrics.requests);
        assert_eq!(par_srv.metrics.tokens_generated, serial_srv.metrics.tokens_generated);
        assert_eq!(par_srv.metrics.decode_steps, serial_srv.metrics.decode_steps);
        assert_eq!(par_srv.metrics.slot_steps_busy, serial_srv.metrics.slot_steps_busy);
        assert_eq!(par_srv.metrics.slot_steps_total, serial_srv.metrics.slot_steps_total);
    }
}

/// Degenerate requests resolve with zero tokens without wedging the pool.
#[test]
fn degenerate_requests_resolve_cleanly() {
    let model = synthetic_model("degenerate");
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = vec![
        (Vec::new(), 3, 0.0),          // empty prompt
        (prompt_bytes(5, 1), 0, 0.0),  // nothing to generate
        (prompt_bytes(5, 2), 4, 0.0),  // a real one
    ];
    let (resps, server) = run_continuous(&q, 2, 8, false, &reqs);
    assert_eq!(resps[0].generated.len(), 0);
    assert_eq!(resps[1].generated.len(), 0);
    assert_eq!(resps[2].generated.len(), 4);
    assert_eq!(server.metrics.requests, 3);
}
