//! Paged KV pool + prefix sharing vs the dense layout and decode oracles.
//!
//! The contract (DESIGN.md §13): the block-paged [`PagedKvCache`] is an
//! observable drop-in for the dense [`KvCache`] — byte-identical K/V rows,
//! token windows, logits and telemetry for any feed sequence, including the
//! slide+rebuild eviction boundary — and `serve_continuous` over the paged
//! pool with cross-request prefix sharing stays **token-identical** to the
//! `DecodePolicy::Reforward` / dense-cached oracles for any traffic
//! interleaving, while page refcounts return to the slot free lists after
//! every request completes (no leaks, [`Server::kv_page_audit`]).

use std::sync::mpsc::channel;

use pcdvq::coordinator::{
    Batcher, BatcherConfig, DecodePolicy, GenRequest, GenResponse, Server, ServingWeights,
};
use pcdvq::model::{
    GptModel, HostForward, KvCache, KvPool, KvStore, PagedKvCache, QuantizedGpt,
};
use pcdvq::proptest::{for_cases, synthetic_tinygpt, tiny_pcdvq};

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the paged-KV testbed.
fn synthetic_model(name: &str) -> GptModel {
    synthetic_tinygpt("pcdvq_paged_tests", name, 53)
}

fn quantize(model: &GptModel) -> QuantizedGpt {
    QuantizedGpt::quantize(model, &tiny_pcdvq())
}

fn prompt_bytes(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 11 + salt * 17 + 3) % 251) as u8).collect()
}

/// Serve `reqs` = (prompt, max_new, temperature) through the continuous
/// loop with an explicit KV layout — all requests pre-queued.
fn run_continuous_paged(
    q: &QuantizedGpt,
    max_slots: usize,
    prefill_chunk: usize,
    kv_page: Option<usize>,
    prefix_share: bool,
    threads: usize,
    reqs: &[(Vec<u8>, usize, f32)],
) -> (Vec<GenResponse>, Server) {
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(max_slots)
        .prefill_chunk(prefill_chunk)
        .kv_page(kv_page.unwrap_or(0)) // 0 selects the dense layout
        .prefix_share(prefix_share)
        .threads(threads)
        .build()
        .unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rxs = Vec::new();
    for (p, max_new, temp) in reqs {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
        rxs.push(rrx);
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps = rxs.iter().map(|r| r.recv().expect("response missing")).collect();
    (resps, server)
}

/// Single-request oracle through the static path with an explicit layout.
fn run_single(
    q: &QuantizedGpt,
    policy: DecodePolicy,
    kv_page: Option<usize>,
    prompt: &[u8],
    max_new: usize,
) -> Vec<u8> {
    let mut server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .decode(policy)
        .kv_page(kv_page.unwrap_or(0)) // 0 selects the dense layout
        .build()
        .unwrap();
    let (rtx, rrx) = channel();
    server
        .process_batch(vec![GenRequest::builder(prompt.to_vec()).max_new(max_new).build(rtx)])
        .unwrap();
    rrx.recv().unwrap().generated
}

/// Assert the pool's no-leak invariant on an idle server: every page the
/// pool ever created is on a slot free list, resident in the prefix trie,
/// or dropped back to the allocator — and no slot chain holds pages.
fn assert_no_leaks(server: &Server, tag: &str) {
    let audit = server.kv_page_audit().expect("paged server has an audit");
    assert_eq!(audit.slot_chain_pages, 0, "{tag}: idle slots hold pages");
    assert_eq!(
        audit.created,
        audit.slot_free_pages + audit.prefix_pages + audit.dropped,
        "{tag}: page leak — audit was {audit:?}"
    );
}

/// Property: for random token streams (crossing the slide+rebuild eviction
/// boundary) and random page sizes, `prefill` + a greedy `decode_step` tail
/// through a [`PagedKvCache`] leave byte-identical tokens, K/V rows,
/// telemetry and logits to the dense [`KvCache`] — the KvStore layouts are
/// observationally equal.
#[test]
fn prop_paged_cache_byte_identical_to_dense() {
    let model = synthetic_model("prop_layout");
    let ctx = model.config.ctx;
    let hf = HostForward::from_quantized(quantize(&model)).unwrap();
    for_cases(4, 0x9A6ED, |g| {
        let n = g.usize_in(1, ctx + 20);
        let stream: Vec<i32> = (0..n).map(|_| g.rng.below(251) as i32).collect();
        let mut dense = KvCache::new(&model.config);
        let dense_logits = hf.prefill(&stream, &mut dense).unwrap();
        for ps in [1usize, 3, ctx / 8, ctx] {
            let pool = KvPool::new(&model.config, ps).unwrap();
            let mut paged = PagedKvCache::new(&model.config, &pool);
            let paged_logits = hf.prefill(&stream, &mut paged).unwrap();
            let tag = format!("case {} ps {ps} len {n}", g.case_seed);
            assert_eq!(paged_logits, dense_logits, "{tag}: prefill logits");
            assert_eq!(paged.tokens(), dense.tokens(), "{tag}: token window");
            assert_eq!(paged.len(), dense.len(), "{tag}: len");
            assert_eq!(paged.total_fed(), dense.total_fed(), "{tag}: total_fed");
            assert_eq!(paged.evictions(), dense.evictions(), "{tag}: evictions");
            for layer in 0..model.config.n_layer {
                let (kd, vd) = dense.layer(layer);
                for pos in 0..dense.len() {
                    assert_eq!(paged.k_row(layer, pos), kd.row(pos), "{tag}: K {layer}/{pos}");
                    assert_eq!(paged.v_row(layer, pos), vd.row(pos), "{tag}: V {layer}/{pos}");
                }
            }
            // greedy decode tail — long enough to slide on most lengths
            let mut dtail = dense.clone();
            let mut dlog = dense_logits.clone();
            let mut plog = paged_logits.clone();
            for step in 0..10 {
                let next = pcdvq::tensor::argmax(&dlog) as i32;
                dlog = hf.decode_step(next, &mut dtail).unwrap();
                plog = {
                    let pnext = pcdvq::tensor::argmax(&plog) as i32;
                    assert_eq!(pnext, next, "{tag} step {step}: argmax");
                    hf.decode_step(pnext, &mut paged).unwrap()
                };
                assert_eq!(plog, dlog, "{tag} step {step}: decode logits");
            }
            assert_eq!(paged.tokens(), dtail.tokens(), "{tag}: post-decode window");
            assert_eq!(paged.evictions(), dtail.evictions(), "{tag}: post-decode slides");
        }
    });
}

/// Property (satellite): interleaved admissions over random shared-prefix
/// families keep paged+shared continuous serving token-identical to the
/// per-request `DecodePolicy::Reforward` oracle, an eviction-crossing
/// request rides along (pinned to the dense static-cached path, whose slide
/// schedule it shares), and after the stream drains every page refcount has
/// returned to a slot free list / the trie — no leaks, even after the trie
/// is cleared.
#[test]
fn prop_interleaved_prefix_families_match_oracles_without_leaks() {
    let model = synthetic_model("prop_families");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    for_cases(3, 0xFA31_11E5, |g| {
        let ps = [2usize, 4, 8][g.usize_in(0, 2)];
        let chunk = [1usize, ps, 16][g.usize_in(0, 2)];
        // two families over distinct shared prefixes, interleaved arrivals
        let mut reqs: Vec<(Vec<u8>, usize, f32)> = Vec::new();
        for fam in 0..2usize {
            let plen = g.usize_in(ps, 3 * ps);
            let prefix = prompt_bytes(plen, 100 + fam + g.case_seed as usize);
            for member in 0..3usize {
                let mut p = prefix.clone();
                let suffix = g.usize_in(1, 2 * ps);
                p.extend((0..suffix).map(|_| g.rng.below(251) as u8));
                let max_new = g.usize_in(1, 6);
                // window fits: the re-forward and cached schedules coincide
                assert!(p.len() + max_new <= ctx + 1);
                // interleave: A0 B0 A1 B1 A2 B2
                let at = member * 2 + fam;
                if at >= reqs.len() {
                    reqs.push((p, max_new, 0.0));
                } else {
                    reqs.insert(at, (p, max_new, 0.0));
                }
            }
        }
        // an eviction-crossing request rides along in the same pool
        reqs.push((prompt_bytes(ctx + 9, g.case_seed as usize), 8, 0.0));

        let (resps, mut server) =
            run_continuous_paged(&q, 2, chunk, Some(ps), true, 0, &reqs);
        let tag = format!("case {} ps {ps} chunk {chunk}", g.case_seed);
        for (i, (resp, (prompt, max_new, _))) in resps.iter().zip(&reqs).enumerate() {
            let oracle = if prompt.len() + max_new <= ctx + 1 {
                run_single(&q, DecodePolicy::Reforward, Some(ps), prompt, *max_new)
            } else {
                // past the boundary the cached slide policy takes over:
                // the dense static-cached run is the oracle there
                run_single(&q, DecodePolicy::KvCached, None, prompt, *max_new)
            };
            assert_eq!(resp.generated, oracle, "{tag} req {i}: diverged from oracle");
        }
        assert_no_leaks(&server, &tag);
        assert!(server.metrics.prefix_hits >= 1, "{tag}: families never shared");
        // dropping the trie releases its pages without disturbing the books
        server.clear_prefix_cache();
        assert_eq!(server.prefix_resident_pages(), 0, "{tag}: trie cleared");
        assert_no_leaks(&server, &format!("{tag} (cleared)"));
    });
}

/// Acceptance: the second request over a resident prefix pays prefill work
/// proportional to the **cold suffix only** — asserted through scheduler
/// steps, the prefix-reuse counters, the pool's page-reuse counters, and
/// the hot/cold TTFT breakdown.
#[test]
fn second_request_over_resident_prefix_prefills_only_the_cold_suffix() {
    let model = synthetic_model("hot_prefix");
    let q = quantize(&model);
    let (ps, chunk, plen, max_new) = (8usize, 8usize, 30usize, 5usize);
    let prompt = prompt_bytes(plen, 7);
    let reqs = vec![(prompt.clone(), max_new, 0.0), (prompt.clone(), max_new, 0.0)];
    // one slot → strictly sequential: A prefills cold + publishes, B hits
    let (resps, server) = run_continuous_paged(&q, 1, chunk, Some(ps), true, 0, &reqs);

    assert_eq!(resps[0].generated, resps[1].generated, "same prompt, same tokens");
    let oracle = run_single(&q, DecodePolicy::Reforward, Some(ps), &prompt, max_new);
    assert_eq!(resps[0].generated, oracle, "hot path still oracle-identical");

    // A: ceil(30/8)=4 prefill steps; B: covered 24 → ceil(6/8)=1 step
    let covered = ((plen - 1) / ps) * ps;
    assert_eq!(covered, 24);
    assert_eq!(resps[0].steps, plen.div_ceil(chunk) + (max_new - 1));
    assert_eq!(
        resps[1].steps,
        (plen - covered).div_ceil(chunk) + (max_new - 1),
        "second request's prefill was not proportional to the cold suffix"
    );
    assert!(resps[1].steps < resps[0].steps);

    assert_eq!(server.metrics.prefix_misses, 1, "A was cold");
    assert_eq!(server.metrics.prefix_hits, 1, "B rode the resident prefix");
    assert_eq!(server.metrics.prefix_tokens_reused, covered as u64);
    assert_eq!(server.metrics.ttft_cold_count(), 1);
    assert_eq!(server.metrics.ttft_hot_count(), 1);

    // page-reuse accounting, exactly: A allocates pages 0..4 (30 prompt +
    // 4 decode tokens), releases the two unshared ones at completion; B
    // attaches the three published pages and recycles the two free buffers
    // — nothing new is allocated for the hot request, and COW never fires
    let c = server.kv_pool_counters().unwrap();
    assert_eq!(c.allocated, 5, "hot request allocated fresh pages: {c:?}");
    assert_eq!(c.reused, 2, "hot request skipped the free list: {c:?}");
    assert_eq!(c.cow_copies, 0, "serving writes never hit shared pages");
    assert_eq!(server.prefix_resident_pages(), covered / ps);
    assert_no_leaks(&server, "hot prefix");
}

/// Sharing is inert where it must be: dense layout ignores `prefix_share`,
/// and paged-without-sharing matches paged-with-sharing token-for-token
/// (the speedup is scheduling, never sampling).
#[test]
fn sharing_toggles_change_work_but_never_tokens() {
    let model = synthetic_model("toggles");
    let q = quantize(&model);
    let prefix = prompt_bytes(24, 1);
    let reqs: Vec<(Vec<u8>, usize, f32)> = (0..4)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend(prompt_bytes(6, 50 + i));
            (p, 4usize, 0.0)
        })
        .collect();
    let (dense, dense_srv) = run_continuous_paged(&q, 2, 8, None, true, 0, &reqs);
    let (noshare, _) = run_continuous_paged(&q, 2, 8, Some(4), false, 0, &reqs);
    let (shared, shared_srv) = run_continuous_paged(&q, 2, 8, Some(4), true, 0, &reqs);
    for (i, ((a, b), c)) in dense.iter().zip(&noshare).zip(&shared).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: dense vs paged");
        assert_eq!(b.generated, c.generated, "req {i}: sharing changed tokens");
    }
    assert!(dense_srv.kv_page_audit().is_none(), "dense server has no pool");
    assert_eq!(dense_srv.metrics.prefix_hits + dense_srv.metrics.prefix_misses, 0);
    assert!(shared_srv.metrics.prefix_tokens_reused > 0, "sharing never engaged");
    assert_no_leaks(&shared_srv, "toggles");
}

/// The §12 determinism contract extends to the paged pool: 1- vs 4-thread
/// runs of shared-prefix traffic produce identical tokens, steps, scheduler
/// counters, pool counters and prefix stats.
#[test]
fn paged_sharing_deterministic_across_thread_counts() {
    let model = synthetic_model("threads");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    let prefix = prompt_bytes(20, 9);
    let mut reqs: Vec<(Vec<u8>, usize, f32)> = (0..5)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend(prompt_bytes(3 + i, 70 + i));
            (p, 3 + (i % 3), 0.0)
        })
        .collect();
    reqs.push((prompt_bytes(ctx + 5, 80), 6, 0.0)); // eviction rides along
    let run = |threads: usize| run_continuous_paged(&q, 3, 8, Some(4), true, threads, &reqs);
    let (serial, serial_srv) = run(1);
    let (par, par_srv) = run(4);
    for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: threads changed tokens");
        assert_eq!(a.steps, b.steps, "req {i}: threads changed steps");
        assert_eq!(a.seq, b.seq, "req {i}: admission order");
    }
    assert_eq!(serial_srv.kv_pool_counters(), par_srv.kv_pool_counters());
    assert_eq!(serial_srv.prefix_resident_pages(), par_srv.prefix_resident_pages());
    let (sm, pm) = (&serial_srv.metrics, &par_srv.metrics);
    assert_eq!(sm.kv_pages_allocated, pm.kv_pages_allocated);
    assert_eq!(sm.kv_pages_reused, pm.kv_pages_reused);
    assert_eq!(sm.kv_pages_released, pm.kv_pages_released);
    assert_eq!(sm.prefix_hits, pm.prefix_hits);
    assert_eq!(sm.prefix_misses, pm.prefix_misses);
    assert_eq!(sm.prefix_tokens_reused, pm.prefix_tokens_reused);
    assert_eq!(sm.prefix_pages_published, pm.prefix_pages_published);
    assert_eq!(sm.decode_steps, pm.decode_steps);
    assert_eq!(sm.slot_steps_busy, pm.slot_steps_busy);
    assert_no_leaks(&serial_srv, "threads=1");
    assert_no_leaks(&par_srv, "threads=4");
}
