//! Chaos suite for the fault-tolerance layer (DESIGN.md §17).
//!
//! The contract under test: a panic or error inside one slot's supervised
//! step fails exactly that request — `FinishReason::Faulted`, SSE
//! `event: error`, fault counters incremented — while every *other*
//! in-flight request finishes token-for-token identical to a fault-free
//! run, at every cell of shards {1,2} × kv_page {0,4} × kv_quant {0,4}.
//! The poisoned slot is quarantined and its KV state rebuilt, so the
//! no-leak page audit still balances afterwards and the slot is reusable.
//! Fault counters obey the §12 determinism contract (invariant under
//! `PALLAS_THREADS` — named CI steps run this suite at 1 and 4 threads).
//!
//! Plus the degradation surfaces: deadlines expiring mid-prefill reclaim
//! the slot as `TimedOut`, dribbling clients get `408` without wedging a
//! handler, and `/readyz` flips 503 while draining as `/healthz` stays up.
//!
//! Injected panics print through the default panic hook — the "thread
//! panicked: injected fault ..." lines in this suite's output are the
//! tests working, not failing.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use pcdvq::coordinator::ingress::{http_request, parse_sse, post_generate};
use pcdvq::coordinator::{
    Batcher, BatcherConfig, FaultMode, FaultPlan, FinishReason, GenRequest, GenResponse, Ingress,
    IngressConfig, Server, ServingWeights,
};
use pcdvq::model::QuantizedGpt;
use pcdvq::proptest::{synthetic_tinygpt, tiny_pcdvq};

fn quantized(name: &str) -> QuantizedGpt {
    let model = synthetic_tinygpt("pcdvq_fault_tolerance_tests", name, 23);
    QuantizedGpt::quantize(&model, &tiny_pcdvq())
}

fn prompt_bytes(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 11 + salt * 17 + 3) % 251) as u8).collect()
}

/// One cell of the fault matrix (same axes as `tests/sharded_decode.rs`).
struct Cell {
    shards: usize,
    kv_page: usize,
    kv_quant: u32,
}

impl Cell {
    fn tag(&self) -> String {
        format!("shards={} kv_page={} kv_quant={}", self.shards, self.kv_page, self.kv_quant)
    }
}

/// Serve pre-queued requests through the continuous loop at one cell,
/// optionally with an armed fault plan. All requests are queued before the
/// loop starts and `max_slots >= reqs.len()`, so admission order, slot
/// assignment, and `request_rng` seeding are identical with and without
/// the fault — exactly the setup the isolation contract is stated for.
fn run_continuous(
    q: &QuantizedGpt,
    cell: &Cell,
    threads: Option<usize>,
    fault: Option<FaultPlan>,
    reqs: &[(Vec<u8>, usize, f32)],
) -> (Vec<GenResponse>, Server) {
    let mut b = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .shards(cell.shards)
        .kv_page(cell.kv_page)
        .kv_quant(cell.kv_quant)
        .max_slots(reqs.len())
        .prefill_chunk(5);
    if let Some(t) = threads {
        b = b.threads(t);
    }
    if let Some(plan) = fault {
        b = b.fault(plan);
    }
    let mut server = b.build().unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rxs = Vec::new();
    for (p, max_new, temp) in reqs {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
        rxs.push(rrx);
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps = rxs.iter().map(|r| r.recv().expect("response missing")).collect();
    (resps, server)
}

/// The traffic mix every matrix cell serves: four requests, slot i ==
/// request i (pre-queued, max_slots 4). Slot 1 is the fault target; its
/// 6-token prompt prefills in two chunk-5 steps, so step 4 lands
/// mid-decode and past the KV-codec freeze point on every topology.
fn chaos_reqs() -> Vec<(Vec<u8>, usize, f32)> {
    vec![
        (prompt_bytes(3, 0), 8, 0.0),
        (prompt_bytes(6, 1), 10, 0.0), // the victim
        (prompt_bytes(9, 2), 8, 0.7),  // sampled: catches RNG-stream perturbation
        (prompt_bytes(12, 3), 6, 0.0),
    ]
}

const VICTIM: usize = 1;
const FAULT_STEP: u64 = 4;

/// Assert the page audit balances with every slot idle — no leaks, no
/// pages stranded on the quarantined slot's chain.
fn assert_no_leaks(server: &Server, cell: &Cell, what: &str) {
    if cell.kv_page == 0 {
        assert!(server.kv_page_audit().is_none(), "{}: dense cell has no audit", cell.tag());
        return;
    }
    let audit = server.kv_page_audit().expect("paged cell audits");
    assert_eq!(audit.slot_chain_pages, 0, "{} at {}: idle slots hold pages", what, cell.tag());
    assert_eq!(
        audit.created,
        audit.slot_free_pages + audit.prefix_pages + audit.dropped,
        "{} at {}: page leak — audit was {audit:?}",
        what,
        cell.tag()
    );
}

/// The headline isolation matrix: at every cell of shards {1,2} ×
/// kv_page {0,4} × kv_quant {0,4}, for both fault modes, a fault injected
/// into slot 1 mid-decode fails exactly that request (`Faulted`, its
/// tokens a strict prefix of the fault-free run's) while the other three
/// requests finish byte-identical — same tokens, steps, seq, and
/// `Done` — and the fault counter reads exactly one (kind, node) hit.
/// Afterwards the quarantined slot's pages are back in the pool.
#[test]
fn faults_isolate_the_affected_request_across_the_topology_matrix() {
    let q = quantized("matrix");
    let reqs = chaos_reqs();

    for shards in [1usize, 2] {
        for kv_page in [0usize, 4] {
            for kv_quant in [0u32, 4] {
                let cell = Cell { shards, kv_page, kv_quant };
                let (baseline, b_server) = run_continuous(&q, &cell, None, None, &reqs);
                assert_eq!(b_server.metrics.faults_total(), 0, "{}: clean run", cell.tag());
                assert!(
                    baseline.iter().all(|r| r.finish == FinishReason::Done),
                    "{}: clean run all Done",
                    cell.tag()
                );

                // inject on the *last* node so sharded supervision is
                // exercised deep in the pipeline, not just at its mouth
                let node = shards - 1;
                for mode in [FaultMode::Panic, FaultMode::Corrupt] {
                    let plan = FaultPlan::new(mode, node, VICTIM, FAULT_STEP);
                    let (resps, server) =
                        run_continuous(&q, &cell, None, Some(plan), &reqs);
                    let tag = format!("{} mode={mode:?}", cell.tag());

                    let victim = &resps[VICTIM];
                    assert_eq!(victim.finish, FinishReason::Faulted, "{tag}: victim finish");
                    assert!(
                        victim.generated.len() < baseline[VICTIM].generated.len(),
                        "{tag}: victim was cut short"
                    );
                    assert!(
                        baseline[VICTIM].generated.starts_with(&victim.generated),
                        "{tag}: victim tokens diverged before the fault"
                    );

                    for i in [0usize, 2, 3] {
                        assert_eq!(
                            resps[i].generated, baseline[i].generated,
                            "{tag}: req {i} tokens perturbed by the fault"
                        );
                        assert_eq!(resps[i].steps, baseline[i].steps, "{tag}: req {i} steps");
                        assert_eq!(resps[i].seq, baseline[i].seq, "{tag}: req {i} seq");
                        assert_eq!(
                            resps[i].finish,
                            FinishReason::Done,
                            "{tag}: req {i} finish"
                        );
                    }

                    let kind = match mode {
                        FaultMode::Panic => "panic",
                        FaultMode::Corrupt => "error",
                    };
                    assert_eq!(
                        server.metrics.faults(),
                        &[(kind.to_string(), node, 1)],
                        "{tag}: fault counter"
                    );
                    assert_eq!(server.metrics.requests, reqs.len() as u64, "{tag}: all respond");
                    assert_no_leaks(&server, &cell, "post-fault");
                }
            }
        }
    }
}

/// §12 extended to faults: the same injected panic at 1 and 4 worker
/// threads yields identical per-request outputs AND identical
/// `(kind, node)` fault counters — supervision happens in the workers,
/// but the fold (and the counter) stays on the coordinator in slot order.
#[test]
fn fault_counters_and_outputs_are_thread_invariant() {
    let q = quantized("threads");
    let reqs = chaos_reqs();
    let cell = Cell { shards: 2, kv_page: 4, kv_quant: 4 };

    let plan = || Some(FaultPlan::new(FaultMode::Panic, 1, VICTIM, FAULT_STEP));
    let (r1, s1) = run_continuous(&q, &cell, Some(1), plan(), &reqs);
    let (r4, s4) = run_continuous(&q, &cell, Some(4), plan(), &reqs);

    for (i, (a, b)) in r1.iter().zip(&r4).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: tokens moved with thread count");
        assert_eq!(a.steps, b.steps, "req {i}: steps moved with thread count");
        assert_eq!(a.finish, b.finish, "req {i}: finish moved with thread count");
    }
    assert_eq!(r1[VICTIM].finish, FinishReason::Faulted, "victim faulted at 1 thread");
    assert_eq!(s1.metrics.faults(), s4.metrics.faults(), "fault counters moved with threads");
    assert_eq!(s1.metrics.faults_total(), 1);
    assert_eq!(s1.metrics.decode_steps, s4.metrics.decode_steps, "decode steps");
    assert_eq!(s1.metrics.tokens_generated, s4.metrics.tokens_generated, "tokens");
}

/// Over the wire, a faulted request terminates its SSE stream with
/// `event: error` — never a silently truncated or hung stream — and the
/// fault shows up in `/metrics` as `pallas_faults_total{kind,node}`.
#[test]
fn faulted_stream_terminates_with_an_sse_error_event() {
    let q = quantized("sse");
    let server = Server::builder(ServingWeights::CodesResident(Box::new(q)))
        .max_slots(2)
        .prefill_chunk(16)
        .fault(FaultPlan::new(FaultMode::Corrupt, 0, 0, 3))
        .build()
        .unwrap();
    let ingress =
        Ingress::spawn(server, BatcherConfig::default(), IngressConfig::default(), "127.0.0.1:0")
            .unwrap();
    let addr = ingress.addr();

    // 2-byte prompt prefills in one chunk-16 step; the fault lands a few
    // decode steps in, with the stream already flowing
    let resp = post_generate(addr, "hi", 64, 0.0, "", 0).unwrap();
    assert_eq!(resp.status, 200, "SSE streams start 200; body: {}", resp.body);
    assert_eq!(resp.header("content-type"), Some("text/event-stream"));
    assert!(resp.body.contains("event: error"), "no error event in: {}", resp.body);
    let events = parse_sse(&resp.body);
    let last = events.last().expect("stream has events");
    assert_eq!(last.event, "error", "stream must END on the error event: {events:?}");
    assert!(last.data.contains("\"error\":\"faulted\""), "error payload: {}", last.data);
    assert!(!events.iter().any(|e| e.event == "usage"), "no usage record for a faulted stream");

    // the mirror publishes at the end of the scheduler iteration — poll
    // rather than racing it
    let t0 = Instant::now();
    let needle = "pallas_faults_total{kind=\"error\",node=\"0\"} 1";
    loop {
        let scrape = http_request(addr, "GET", "/metrics", None).unwrap();
        if scrape.body.contains(needle) {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "fault never scraped: {}", scrape.body);
        std::thread::yield_now();
    }

    // the slot was quarantined and rebuilt: the next request serves fine
    let resp = post_generate(addr, "hi again", 4, 0.0, "", 0).unwrap();
    assert_eq!(resp.status, 200);
    let events = parse_sse(&resp.body);
    assert_eq!(events.last().unwrap().event, "usage", "post-fault stream completes");

    let server = ingress.shutdown().unwrap();
    assert_eq!(server.metrics.faults_total(), 1);
}

/// Slowloris: a client that sends half a request line and then stalls is
/// cut off with `408 Request Timeout` once the read budget expires — it
/// cannot wedge a handler — and the server keeps serving normal traffic.
#[test]
fn slowloris_dribbler_gets_408_and_the_handler_survives() {
    let q = quantized("slowloris");
    let server = Server::builder(ServingWeights::CodesResident(Box::new(q)))
        .max_slots(2)
        .prefill_chunk(16)
        .build()
        .unwrap();
    let cfg = IngressConfig {
        read_timeout: Duration::from_millis(200),
        ..IngressConfig::default()
    };
    let ingress =
        Ingress::spawn(server, BatcherConfig::default(), cfg, "127.0.0.1:0").unwrap();
    let addr = ingress.addr();

    let mut dribbler = TcpStream::connect(addr).unwrap();
    dribbler.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // half a request line, then silence — the server's read blocks until
    // its 200ms budget expires
    dribbler.write_all(b"POST /v1/gen").unwrap();
    dribbler.flush().unwrap();
    let mut raw = String::new();
    dribbler.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 408"), "expected 408, got: {raw}");
    assert!(raw.contains("read timed out"), "timeout body: {raw}");

    // a header-phase dribbler is cut off the same way
    let mut dribbler = TcpStream::connect(addr).unwrap();
    dribbler.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    dribbler.write_all(b"POST /v1/generate HTTP/1.1\r\ncontent-le").unwrap();
    dribbler.flush().unwrap();
    let mut raw = String::new();
    dribbler.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 408"), "header dribbler: {raw}");

    // the handlers survived: normal traffic still flows
    let resp = post_generate(addr, "hello", 4, 0.0, "", 0).unwrap();
    assert_eq!(resp.status, 200, "server wedged after slowloris: {}", resp.body);
    assert_eq!(parse_sse(&resp.body).last().unwrap().event, "usage");

    let server = ingress.shutdown().unwrap();
    assert_eq!(server.metrics.requests, 1, "dribblers never reached the scheduler");
}

/// `/healthz` is liveness (always 200 while the process accepts), and
/// `/readyz` is readiness: 200 once the scheduler is looping, 503 with a
/// reason once draining begins — while `/healthz` stays green so an
/// orchestrator restarts nothing during a graceful drain.
#[test]
fn readyz_flips_through_the_serving_lifecycle() {
    let q = quantized("readyz");
    let server = Server::builder(ServingWeights::CodesResident(Box::new(q)))
        .max_slots(2)
        .prefill_chunk(16)
        .build()
        .unwrap();
    let ingress =
        Ingress::spawn(server, BatcherConfig::default(), IngressConfig::default(), "127.0.0.1:0")
            .unwrap();
    let addr = ingress.addr();

    // starting → ready: poll until the scheduler's first iteration flips
    // the latch (any 503 before that must say why)
    let t0 = Instant::now();
    loop {
        let r = http_request(addr, "GET", "/readyz", None).unwrap();
        if r.status == 200 {
            assert!(r.body.contains("ready"), "ready body: {}", r.body);
            break;
        }
        assert_eq!(r.status, 503, "readyz is 200 or 503, got {}", r.status);
        assert!(r.body.contains("starting"), "pre-ready body: {}", r.body);
        assert!(t0.elapsed() < Duration::from_secs(10), "server never became ready");
        std::thread::yield_now();
    }
    assert_eq!(http_request(addr, "GET", "/healthz", None).unwrap().status, 200);

    let resp = post_generate(addr, "warm", 4, 0.0, "", 0).unwrap();
    assert_eq!(resp.status, 200);

    ingress.begin_drain();
    let r = http_request(addr, "GET", "/readyz", None).unwrap();
    assert_eq!(r.status, 503, "draining server must fail readiness");
    assert!(r.body.contains("draining"), "drain body: {}", r.body);
    assert_eq!(
        http_request(addr, "GET", "/healthz", None).unwrap().status,
        200,
        "liveness stays green through a drain"
    );

    ingress.shutdown().unwrap();
}

/// A deadline that expires mid-prefill finishes the request as `TimedOut`
/// with its slot and pages reclaimed — the no-leak audit balances — and
/// the very next admission reuses the slot and decodes exactly what a
/// solo greedy run produces.
#[test]
fn deadline_expiring_mid_prefill_reclaims_the_slot() {
    let q = quantized("deadline");
    let build = || {
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(1)
            .prefill_chunk(1)
            .kv_page(4)
            .build()
            .unwrap()
    };

    // solo reference for the survivor (greedy, so seq-seeded RNG is moot)
    let follow_up = prompt_bytes(8, 1);
    let mut server = build();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let (rtx, rrx) = channel();
    batcher.push(GenRequest::builder(follow_up.clone()).max_new(6).build(rtx));
    server.serve_continuous(&mut batcher).unwrap();
    let solo = rrx.recv().unwrap().generated;

    // deadlines at 0ms (expires before the first chunk) and 1ms (a
    // 60-chunk prefill plus thousands of decode steps dwarfs it, so it
    // expires somewhere inside prefill): both must reclaim identically
    for deadline in [Duration::ZERO, Duration::from_millis(1)] {
        let mut server = build();
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (dtx, drx) = channel();
        batcher.push(
            GenRequest::builder(prompt_bytes(60, 0))
                .max_new(4000)
                .deadline_in(deadline)
                .build(dtx),
        );
        let (ftx, frx) = channel();
        batcher.push(GenRequest::builder(follow_up.clone()).max_new(6).build(ftx));
        server.serve_continuous(&mut batcher).unwrap();

        let doomed = drx.recv().unwrap();
        assert_eq!(
            doomed.finish,
            FinishReason::TimedOut,
            "deadline {deadline:?}: 4000 tokens cannot beat it"
        );
        assert!(doomed.generated.len() < 4000, "deadline {deadline:?}: cut short");

        let survivor = frx.recv().unwrap();
        assert_eq!(survivor.finish, FinishReason::Done, "deadline {deadline:?}");
        assert_eq!(
            survivor.generated, solo,
            "deadline {deadline:?}: reused slot diverged from the solo run"
        );

        assert!(server.metrics.timeouts >= 1, "deadline {deadline:?}: timeout counted");
        let audit = server.kv_page_audit().expect("paged server audits");
        assert_eq!(audit.slot_chain_pages, 0, "deadline {deadline:?}: slot still holds pages");
        assert_eq!(
            audit.created,
            audit.slot_free_pages + audit.prefix_pages + audit.dropped,
            "deadline {deadline:?}: page leak — audit was {audit:?}"
        );
    }
}
