//! Cross-language IO: rust reads what python wrote (and vice versa via a
//! subprocess), plus the trained-artifact containers themselves.

use pcdvq::config::Paths;
use pcdvq::io::{Entry, Pct};

#[test]
fn rust_reads_python_written_containers() {
    // the build artifacts were written by python/compile/pct.py
    let paths = Paths::detect();
    let corpus = paths.artifacts.join("corpus_eval.pct");
    if !corpus.exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let pct = Pct::load(&corpus).unwrap();
    let tokens = pct.get("tokens").unwrap().as_u32().unwrap();
    assert!(tokens.len() > 10_000);
    assert!(tokens.iter().all(|&t| t < 256));

    let model = Pct::load(paths.artifacts.join("gpt-mini.pct")).unwrap();
    assert!(model.contains("embed.tok"));
    assert_eq!(model.get("meta.vocab").unwrap().scalar_u64().unwrap(), 256);
    let e = model.get("embed.tok").unwrap();
    assert_eq!(e.dims, vec![256, 128]);
    assert!(e.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn python_reads_rust_written_container() {
    // write with rust, read back with python/compile/pct.py in a subprocess
    let dir = std::env::temp_dir().join("pcdvq_xlang");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rust_written.pct");
    let mut p = Pct::new();
    p.insert("w", Entry::f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 1e-7, -9.0]));
    p.insert("idx", Entry::u32(&[4], vec![0, 7, 42, u32::MAX]));
    p.insert("seed", Entry::u64(&[1], vec![0xDEAD_BEEF_CAFE]));
    p.save(&path).unwrap();

    let script = format!(
        "import sys; sys.path.insert(0, '{root}/python')\n\
         from compile import pct\n\
         import numpy as np\n\
         d = pct.load('{path}')\n\
         assert d['w'].shape == (2, 3), d['w'].shape\n\
         assert abs(d['w'][1, 0] - 3.25) < 1e-9\n\
         assert d['idx'][3] == 2**32 - 1\n\
         assert d['seed'][0] == 0xDEADBEEFCAFE\n\
         print('XLANG_OK')",
        root = env!("CARGO_MANIFEST_DIR"),
        path = path.display()
    );
    let out = std::process::Command::new("python")
        .arg("-c")
        .arg(&script)
        .output()
        .expect("python not runnable");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("XLANG_OK"),
        "python failed to read rust PCT1: {}\n{}",
        stdout,
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn manifest_agrees_with_model_container() {
    let paths = Paths::detect();
    let man_path = paths.artifacts.join("fwd_fp_gpt-mini_b8.manifest");
    if !man_path.exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let manifest = pcdvq::runtime::Manifest::load(&man_path).unwrap();
    let model = paths.load_model("gpt-mini").unwrap();
    // every non-token manifest input exists in the container with matching
    // element counts
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let t = model.tensor(&e.name).unwrap();
        assert_eq!(t.len(), e.element_count(), "{}", e.name);
    }
    // and the sorted order matches (BTreeMap ↔ python sorted())
    let names: Vec<&str> = manifest
        .entries
        .iter()
        .map(|e| e.name.as_str())
        .filter(|n| *n != "tokens")
        .collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "manifest weights not in sorted order");
}
