//! Cross-language IO: rust reads what python wrote (and vice versa via a
//! subprocess), plus the trained-artifact containers themselves — and the
//! integrity seal on quantized artifacts (DESIGN.md §17): a flipped byte
//! anywhere in a saved `.pctq` fails the load with an error naming the
//! damaged section, never a silent wrong-logits model.

use pcdvq::config::Paths;
use pcdvq::io::{load_quantized, save_quantized, Entry, Pct};
use pcdvq::model::QuantizedGpt;
use pcdvq::proptest::{synthetic_tinygpt, tiny_pcdvq};

#[test]
fn rust_reads_python_written_containers() {
    // the build artifacts were written by python/compile/pct.py
    let paths = Paths::detect();
    let corpus = paths.artifacts.join("corpus_eval.pct");
    if !corpus.exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let pct = Pct::load(&corpus).unwrap();
    let tokens = pct.get("tokens").unwrap().as_u32().unwrap();
    assert!(tokens.len() > 10_000);
    assert!(tokens.iter().all(|&t| t < 256));

    let model = Pct::load(paths.artifacts.join("gpt-mini.pct")).unwrap();
    assert!(model.contains("embed.tok"));
    assert_eq!(model.get("meta.vocab").unwrap().scalar_u64().unwrap(), 256);
    let e = model.get("embed.tok").unwrap();
    assert_eq!(e.dims, vec![256, 128]);
    assert!(e.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn python_reads_rust_written_container() {
    // write with rust, read back with python/compile/pct.py in a subprocess
    let dir = std::env::temp_dir().join("pcdvq_xlang");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rust_written.pct");
    let mut p = Pct::new();
    p.insert("w", Entry::f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 1e-7, -9.0]));
    p.insert("idx", Entry::u32(&[4], vec![0, 7, 42, u32::MAX]));
    p.insert("seed", Entry::u64(&[1], vec![0xDEAD_BEEF_CAFE]));
    p.save(&path).unwrap();

    let script = format!(
        "import sys; sys.path.insert(0, '{root}/python')\n\
         from compile import pct\n\
         import numpy as np\n\
         d = pct.load('{path}')\n\
         assert d['w'].shape == (2, 3), d['w'].shape\n\
         assert abs(d['w'][1, 0] - 3.25) < 1e-9\n\
         assert d['idx'][3] == 2**32 - 1\n\
         assert d['seed'][0] == 0xDEADBEEFCAFE\n\
         print('XLANG_OK')",
        root = env!("CARGO_MANIFEST_DIR"),
        path = path.display()
    );
    let out = std::process::Command::new("python")
        .arg("-c")
        .arg(&script)
        .output()
        .expect("python not runnable");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("XLANG_OK"),
        "python failed to read rust PCT1: {}\n{}",
        stdout,
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Save a real quantized tinygpt and return (artifact bytes, path dir).
fn saved_artifact(name: &str) -> (Vec<u8>, std::path::PathBuf) {
    let model = synthetic_tinygpt("pcdvq_xlang_integrity", name, 23);
    let q = QuantizedGpt::quantize(&model, &tiny_pcdvq());
    let dir = std::env::temp_dir().join("pcdvq_xlang_integrity");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{name}.pctq"));
    save_quantized(&q, &path).unwrap();
    (std::fs::read(&path).unwrap(), dir)
}

/// Tampering with a section's payload without re-sealing is rejected on
/// load with an error that names the damaged section — here a codebook
/// entry, so the message must say `codebooks`.
#[test]
fn tampered_codebook_is_rejected_naming_its_section() {
    let (_, dir) = saved_artifact("tamper");
    let path = dir.join("tamper.pctq");

    let mut pct = Pct::load(&path).unwrap();
    let cb = pct
        .names()
        .find(|n| n.starts_with("codebook."))
        .expect("quantized artifact carries codebooks")
        .to_string();
    let entry = pct.get(&cb).unwrap();
    let dims = entry.dims.clone();
    let mut data = entry.as_f32().unwrap().to_vec();
    data[0] += 0.5;
    pct.insert(&cb, Entry::f32(&dims, data));
    let evil = dir.join("tamper_evil.pctq");
    pct.save(&evil).unwrap();

    let err = load_quantized(&evil, "x").unwrap_err().to_string();
    assert!(err.contains("section 'codebooks'"), "should name the section: {err}");
    assert!(err.contains("corrupted"), "should say corrupted: {err}");
    // the untampered original still loads
    load_quantized(&path, "x").unwrap();
}

/// Flip one byte at offsets spread through the whole file: every variant
/// must fail the load (CRC mismatch, count mismatch, or a parse error for
/// structural bytes) — and the CRC path's message names a section.
#[test]
fn any_flipped_byte_fails_the_load() {
    let (bytes, dir) = saved_artifact("byteflip");
    assert!(bytes.len() > 64, "artifact suspiciously small: {} bytes", bytes.len());

    let mut named_a_section = 0usize;
    let n_probes = 24usize;
    for i in 0..n_probes {
        // skew probes toward the front (header, names, metadata) but walk
        // the payload tail too
        let offset = (i * (bytes.len() - 1)) / (n_probes - 1);
        let mut evil = bytes.clone();
        evil[offset] ^= 0x40;
        let path = dir.join(format!("byteflip_{offset}.pctq"));
        std::fs::write(&path, &evil).unwrap();
        let err = match load_quantized(&path, "x") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("flipped byte at offset {offset} loaded clean"),
        };
        if err.contains("section '") && err.contains("corrupted") {
            named_a_section += 1;
        }
    }
    // deep-payload flips land in CRC territory, so most probes must have
    // produced the structured section-naming error (not just parse noise)
    assert!(
        named_a_section >= n_probes / 2,
        "only {named_a_section}/{n_probes} probes named a section"
    );
}

#[test]
fn manifest_agrees_with_model_container() {
    let paths = Paths::detect();
    let man_path = paths.artifacts.join("fwd_fp_gpt-mini_b8.manifest");
    if !man_path.exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let manifest = pcdvq::runtime::Manifest::load(&man_path).unwrap();
    let model = paths.load_model("gpt-mini").unwrap();
    // every non-token manifest input exists in the container with matching
    // element counts
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let t = model.tensor(&e.name).unwrap();
        assert_eq!(t.len(), e.element_count(), "{}", e.name);
    }
    // and the sorted order matches (BTreeMap ↔ python sorted())
    let names: Vec<&str> = manifest
        .entries
        .iter()
        .map(|e| e.name.as_str())
        .filter(|n| *n != "tokens")
        .collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "manifest weights not in sorted order");
}
