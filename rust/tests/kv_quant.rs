//! Quantized KV cache (DESIGN.md §15) vs the exact layout and its oracles.
//!
//! The contract: `--kv-quant 0` is **byte-identical** to the unquantized
//! serving path (the parity oracle); at 2..=8 cache bits the polar-decoupled
//! codec trades logit fidelity for resident bits behind a hard quality gate
//! (quantized-cache perplexity within a per-bit-width tolerance of the
//! exact-cache perplexity, via [`pcdvq::eval::KvQuantForward`] +
//! `evaluate_ppl`'s session path); serving stays deterministic across thread
//! counts and KV layouts (DESIGN.md §12/§13 extend to code-carrying pages);
//! and slide+rebuild eviction re-quantizes rebuilt rows against the *frozen*
//! per-layer codebooks — never rebuilding them.
//!
//! CI drives this suite under `PALLAS_THREADS={1,4}` × `PALLAS_KV_PAGE={4,0}`.

use std::sync::mpsc::channel;
use std::sync::Arc;

use pcdvq::coordinator::{
    Batcher, BatcherConfig, GenRequest, GenResponse, Server, ServingWeights,
};
use pcdvq::eval::{evaluate_ppl, DecodeSession, ForwardPass, KvQuantForward};
use pcdvq::model::{GptModel, HostForward, KvCache, KvPool, PagedKvCache, QuantizedGpt};
use pcdvq::paper::verify_kv_cache_resident;
use pcdvq::proptest::{for_cases, synthetic_tinygpt, tiny_pcdvq};
use pcdvq::quant::kv::{KvQuantCodec, KvQuantSpec};
use pcdvq::tensor::argmax;

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the quantized-cache testbed.
fn synthetic_model(name: &str) -> GptModel {
    synthetic_tinygpt("pcdvq_kvq_tests", name, 53)
}

fn quantize(model: &GptModel) -> QuantizedGpt {
    QuantizedGpt::quantize(model, &tiny_pcdvq())
}

fn prompt_bytes(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 11 + salt * 17 + 3) % 251) as u8).collect()
}

/// Serve pre-queued `reqs` = (prompt, max_new, temperature) through the
/// continuous loop. `kv_quant` None keeps the server's env default;
/// `Some(0)` pins the exact codec; `kv_page` 0 selects the dense layout.
#[allow(clippy::too_many_arguments)]
fn run_continuous(
    q: &QuantizedGpt,
    kv_quant: Option<u32>,
    kv_page: usize,
    prefix_share: bool,
    threads: usize,
    max_slots: usize,
    chunk: usize,
    reqs: &[(Vec<u8>, usize, f32)],
) -> (Vec<GenResponse>, Server) {
    let mut builder = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .max_slots(max_slots)
        .prefill_chunk(chunk)
        .kv_page(kv_page)
        .prefix_share(prefix_share)
        .threads(threads);
    if let Some(bits) = kv_quant {
        builder = builder.kv_quant(bits);
    }
    let mut server = builder.build().unwrap();
    let (tx, rx) = channel::<GenRequest>();
    drop(tx);
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rxs = Vec::new();
    for (p, max_new, temp) in reqs {
        let (rtx, rrx) = channel();
        batcher.push(GenRequest::builder(p.clone()).max_new(*max_new).temperature(*temp).build(rtx));
        rxs.push(rrx);
    }
    server.serve_continuous(&mut batcher).unwrap();
    let resps = rxs.iter().map(|r| r.recv().expect("response missing")).collect();
    (resps, server)
}

fn assert_no_leaks(server: &Server, tag: &str) {
    let audit = server.kv_page_audit().expect("paged server has an audit");
    assert_eq!(audit.slot_chain_pages, 0, "{tag}: idle slots hold pages");
    assert_eq!(
        audit.created,
        audit.slot_free_pages + audit.prefix_pages + audit.dropped,
        "{tag}: page leak — audit was {audit:?}"
    );
}

/// Acceptance: `--kv-quant 0` is the exact codec — byte-identical tokens,
/// steps and cache accounting vs a server that never saw the flag, on both
/// the paged and dense layouts.
#[test]
fn kv_quant_zero_is_byte_identical_to_the_unquantized_path() {
    let env_quant = std::env::var("PALLAS_KV_QUANT").unwrap_or_default();
    if !env_quant.trim().is_empty() && env_quant.trim() != "0" {
        // the baseline server would inherit a quantized env default and the
        // comparison below would (correctly) refuse to hold
        return;
    }
    let model = synthetic_model("oracle0");
    let q = quantize(&model);
    let reqs: Vec<(Vec<u8>, usize, f32)> = (0..4)
        .map(|i| (prompt_bytes(12 + 5 * i, i), 5, if i % 2 == 0 { 0.0 } else { 0.8 }))
        .collect();
    for ps in [4usize, 0] {
        let (base, base_srv) = run_continuous(&q, None, ps, true, 0, 2, 8, &reqs);
        let (zero, zero_srv) = run_continuous(&q, Some(0), ps, true, 0, 2, 8, &reqs);
        for (i, (a, b)) in base.iter().zip(&zero).enumerate() {
            assert_eq!(a.generated, b.generated, "ps {ps} req {i}: --kv-quant 0 changed tokens");
            assert_eq!(a.steps, b.steps, "ps {ps} req {i}: --kv-quant 0 changed steps");
        }
        assert_eq!(base_srv.kv_cache_bits(), zero_srv.kv_cache_bits(), "ps {ps}: cache bits");
        assert!(zero_srv.kv_codec().is_none(), "ps {ps}: bits 0 must not build a codec");
        assert_eq!(zero_srv.kv_codebook_bits(), 0, "ps {ps}: exact cache has no codebooks");
        assert_eq!(zero_srv.kv_cache_bpw(), 32.0, "ps {ps}: exact cache is 32 bpw");
        assert_eq!(zero_srv.metrics.kv_decoded_subvecs, 0, "ps {ps}: exact cache decodes nothing");
        assert_eq!(verify_kv_cache_resident(&zero_srv).unwrap(), 1.0, "ps {ps}: exact ratio");
    }
}

/// The {8, 6, 4}-bit sweep: teacher-forced greedy agreement with the exact
/// session (the same token stream feeds both, so mismatches never compound)
/// and max absolute logit drift per bit width. Floors are generous — the
/// synthetic model is random-weight — but the trend must hold: more cache
/// bits, more agreement.
#[test]
fn cache_bits_sweep_reports_match_rate_and_bounded_drift() {
    let model = synthetic_model("sweep");
    let cfg = &model.config;
    let hf = HostForward::from_quantized(quantize(&model)).unwrap();

    // exact reference stream: greedy tokens + the logits at every position
    let prompt: Vec<i32> = prompt_bytes(40, 3).iter().map(|&b| b as i32).collect();
    let n_steps = 20usize;
    let mut exact = hf.begin_session().expect("host backend has sessions");
    let mut exact_logits = vec![exact.prefill(&prompt).unwrap()];
    let mut stream = Vec::with_capacity(n_steps);
    for _ in 0..n_steps {
        let next = argmax(exact_logits.last().unwrap()) as i32;
        stream.push(next);
        exact_logits.push(exact.step(next).unwrap());
    }

    let mut sweep: Vec<(u32, f64, f32)> = Vec::new();
    for bits in [8u32, 6, 4] {
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(bits).unwrap(),
            cfg.n_layer,
            cfg.d_model,
            0xBEEF ^ bits as u64,
        ));
        let qf = KvQuantForward::new(&hf, codec.clone());
        let mut sess = qf.begin_session().expect("quantized wrapper has sessions");
        let mut logits = sess.prefill(&prompt).unwrap();
        let (mut matches, mut drift) = (0usize, 0.0f32);
        for (i, &tok) in stream.iter().enumerate() {
            let e = &exact_logits[i];
            if argmax(&logits) == argmax(e) {
                matches += 1;
            }
            for (a, b) in logits.iter().zip(e) {
                drift = drift.max((a - b).abs());
            }
            logits = sess.step(tok).unwrap();
        }
        assert!(drift.is_finite(), "{bits}-bit cache produced non-finite logits");
        assert!(codec.frozen(), "{bits}-bit codec never froze during prefill");
        assert!(codec.codebook_bits() > 0, "{bits}-bit codec has empty codebooks");
        sweep.push((bits, matches as f64 / n_steps as f64, drift));
    }
    assert!(sweep[0].1 >= 0.40, "8-bit cache agreement collapsed: {sweep:?}");
    assert!(sweep[1].1 >= 0.20, "6-bit cache agreement collapsed: {sweep:?}");
    assert!(sweep[2].1 >= 0.05, "4-bit cache agreement collapsed: {sweep:?}");
    assert!(
        sweep[0].1 + 0.30 >= sweep[2].1,
        "8-bit cache agrees less than 4-bit beyond slack: {sweep:?}"
    );
}

/// The hard quality gate: quantized-cache perplexity (through the stateful
/// session path `evaluate_ppl` uses at batch 1) must stay within a
/// per-bit-width factor of the exact-cache perplexity.
#[test]
fn ppl_delta_gate_at_8_and_4_cache_bits() {
    let model = synthetic_model("pplgate");
    let cfg = &model.config;
    let hf = HostForward::from_quantized(quantize(&model)).unwrap();
    let n = cfg.ctx * 3 + 1;
    let tokens: Vec<u32> = (0..n).map(|i| ((i * 7 + 13) % 251) as u32).collect();
    let exact = evaluate_ppl(&hf, cfg, &tokens, 1, 3, 1.0).unwrap();
    assert!(exact.ppl.is_finite() && exact.ppl > 0.0);

    for (bits, tol) in [(8u32, 1.5f64), (4, 3.0)] {
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(bits).unwrap(),
            cfg.n_layer,
            cfg.d_model,
            0x99E1 ^ bits as u64,
        ));
        let qf = KvQuantForward::new(&hf, codec.clone());
        let quant = evaluate_ppl(&qf, cfg, &tokens, 1, 3, 1.0).unwrap();
        assert_eq!(quant.n_tokens, exact.n_tokens, "{bits}-bit eval scored fewer positions");
        assert!(quant.ppl.is_finite(), "{bits}-bit cache ppl is not finite");
        assert!(
            quant.ppl <= exact.ppl * tol,
            "ppl gate failed at {bits} cache bits: quantized {:.3} vs exact {:.3} (tol x{tol})",
            quant.ppl,
            exact.ppl,
        );
        assert!(codec.frozen(), "{bits}-bit codec never froze during eval");
        assert!(codec.decoded_subvecs() > 0, "{bits}-bit eval never touched the LUT");
    }
}

/// The §12 determinism contract under a quantized cache: 1- vs 4-thread runs
/// produce identical tokens, steps, counters and — critically — identical
/// *frozen codebooks* (the first K/V row is observed on the coordinator
/// thread, never racing the slot fan-out). The paged and dense layouts stay
/// drop-in equal with codes in the pages, and the accounting identities
/// (`kv_cache_bpw`, codebook bits, metrics gauges) hold.
#[test]
fn quantized_serving_is_layout_and_thread_invariant() {
    let model = synthetic_model("threads_q");
    let q = quantize(&model);
    let prefix = prompt_bytes(20, 9);
    let reqs: Vec<(Vec<u8>, usize, f32)> = (0..5)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend(prompt_bytes(3 + i, 70 + i));
            (p, 3 + (i % 3), if i == 4 { 0.8 } else { 0.0 })
        })
        .collect();
    let run =
        |page: usize, threads: usize| run_continuous(&q, Some(4), page, true, threads, 3, 8, &reqs);
    let (serial, s_srv) = run(4, 1);
    let (par, p_srv) = run(4, 4);
    let (dense, d_srv) = run(0, 1);

    for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: threads changed tokens");
        assert_eq!(a.steps, b.steps, "req {i}: threads changed steps");
        assert_eq!(a.seq, b.seq, "req {i}: admission order");
    }
    for (i, (a, b)) in serial.iter().zip(&dense).enumerate() {
        assert_eq!(a.generated, b.generated, "req {i}: paged vs dense quantized diverged");
    }

    let (sm, pm) = (&s_srv.metrics, &p_srv.metrics);
    assert_eq!(sm.decode_steps, pm.decode_steps);
    assert_eq!(sm.slot_steps_busy, pm.slot_steps_busy);
    assert_eq!(sm.kv_pages_allocated, pm.kv_pages_allocated);
    assert_eq!(sm.kv_pages_reused, pm.kv_pages_reused);
    assert_eq!(sm.prefix_hits, pm.prefix_hits);
    assert_eq!(sm.prefix_tokens_reused, pm.prefix_tokens_reused);
    assert_eq!(sm.kv_decoded_subvecs, pm.kv_decoded_subvecs, "decode-tile counter raced");
    assert!(sm.kv_decoded_subvecs > 0, "quantized serving never encoded a row");
    assert_eq!(sm.kv_cache_codebook_bits, pm.kv_cache_codebook_bits, "codebooks raced");
    assert_eq!(sm.kv_cache_resident_bits, pm.kv_cache_resident_bits);

    // identical frozen codebooks across layouts too (same seed row)
    assert_eq!(s_srv.kv_codebook_bits(), d_srv.kv_codebook_bits());

    // accounting identities: gauges mirror the accessors, bpw is the
    // word-aligned code rate, the verifier's ratio beats 4x
    let codec = s_srv.kv_codec().expect("quantized server has a codec");
    assert_eq!(codec.spec().bits(), 4);
    assert_eq!(s_srv.kv_codebook_bits(), codec.codebook_bits());
    assert_eq!(sm.kv_cache_codebook_bits, s_srv.kv_codebook_bits());
    assert_eq!(sm.kv_cache_resident_bits, s_srv.kv_cache_bits());
    assert_eq!(sm.kv_cache_bpw, s_srv.kv_cache_bpw());
    assert!(
        s_srv.kv_cache_bpw() >= 4.0 && s_srv.kv_cache_bpw() < 32.0,
        "4-bit cache bpw out of range: {}",
        s_srv.kv_cache_bpw()
    );
    let ratio = verify_kv_cache_resident(&s_srv).unwrap();
    assert!(ratio > 2.0, "4-bit cache compression ratio too small: {ratio}");
    assert_no_leaks(&s_srv, "threads=1");
    assert_no_leaks(&p_srv, "threads=4");
}

/// Property (satellite): interleaved shared-prefix families with
/// code-carrying pages — attach/publish, COW bookkeeping, eviction and the
/// no-leak audit hold at random bit widths, page sizes and chunk sizes;
/// outputs and counters are bit-identical across thread counts; the dense
/// layout stays a drop-in for the same traffic.
#[test]
fn prop_quantized_prefix_families_stay_deterministic_without_leaks() {
    let model = synthetic_model("prop_q");
    let ctx = model.config.ctx;
    let q = quantize(&model);
    for_cases(3, 0x4B56_5172, |g| {
        let bits = [4u32, 6, 8][g.usize_in(0, 2)];
        let ps = [2usize, 4, 8][g.usize_in(0, 2)];
        let chunk = [1usize, ps, 16][g.usize_in(0, 2)];
        let mut reqs: Vec<(Vec<u8>, usize, f32)> = Vec::new();
        for fam in 0..2usize {
            let plen = g.usize_in(ps + 1, 3 * ps);
            let prefix = prompt_bytes(plen, 100 + fam + g.case_seed as usize);
            for member in 0..3usize {
                let mut p = prefix.clone();
                let suffix = g.usize_in(1, 2 * ps);
                p.extend((0..suffix).map(|_| g.rng.below(251) as u8));
                let max_new = g.usize_in(1, 6);
                assert!(p.len() + max_new <= ctx + 1);
                let at = member * 2 + fam;
                if at >= reqs.len() {
                    reqs.push((p, max_new, 0.0));
                } else {
                    reqs.insert(at, (p, max_new, 0.0));
                }
            }
        }
        // an eviction-crossing request re-quantizes its rebuilt window in
        // the same pool, against the already-frozen codebooks
        reqs.push((prompt_bytes(ctx + 9, g.case_seed as usize), 8, 0.0));

        let run = |page: usize, threads: usize| {
            run_continuous(&q, Some(bits), page, true, threads, 2, chunk, &reqs)
        };
        let (serial, s_srv) = run(ps, 1);
        let (par, p_srv) = run(ps, 4);
        let (dense, _) = run(0, 1);
        let tag = format!("case {} bits {bits} ps {ps} chunk {chunk}", g.case_seed);
        for (i, ((a, b), c)) in serial.iter().zip(&par).zip(&dense).enumerate() {
            assert_eq!(a.generated, b.generated, "{tag} req {i}: threads changed tokens");
            assert_eq!(a.steps, b.steps, "{tag} req {i}: threads changed steps");
            assert_eq!(a.seq, b.seq, "{tag} req {i}: admission order");
            assert_eq!(a.generated, c.generated, "{tag} req {i}: paged vs dense diverged");
        }
        assert_eq!(s_srv.kv_pool_counters(), p_srv.kv_pool_counters(), "{tag}: pool counters");
        assert_eq!(
            s_srv.prefix_resident_pages(),
            p_srv.prefix_resident_pages(),
            "{tag}: trie size"
        );
        let (sm, pm) = (&s_srv.metrics, &p_srv.metrics);
        assert_eq!(sm.kv_decoded_subvecs, pm.kv_decoded_subvecs, "{tag}: decode counter");
        assert_eq!(sm.kv_cache_codebook_bits, pm.kv_cache_codebook_bits, "{tag}: codebooks");
        assert_eq!(sm.prefix_hits, pm.prefix_hits, "{tag}: prefix hits");
        assert_eq!(sm.prefix_pages_published, pm.prefix_pages_published, "{tag}: published");
        assert!(sm.prefix_hits >= 1, "{tag}: families never shared a quantized page");
        assert!(sm.kv_decoded_subvecs > 0, "{tag}: codec never engaged");
        assert_no_leaks(&s_srv, &format!("{tag} threads=1"));
        assert_no_leaks(&p_srv, &format!("{tag} threads=4"));
    });
}

/// Regression (satellite): slide+rebuild eviction must re-quantize the
/// rebuilt rows against the **frozen** layer codebooks — never rebuild the
/// codebooks. After evicting past capacity, the surviving window's codes,
/// decoded tiles and logits equal a fresh quantized prefill of exactly that
/// window under the same (already frozen) codec, on both cache layouts.
#[test]
fn eviction_requantizes_rebuilt_rows_against_the_frozen_codebook() {
    let model = synthetic_model("evict_q");
    let cfg = &model.config;
    let hf = HostForward::from_quantized(quantize(&model)).unwrap();
    for bits in [8u32, 4] {
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(bits).unwrap(),
            cfg.n_layer,
            cfg.d_model,
            0xE71C ^ bits as u64,
        ));
        let stream: Vec<i32> =
            (0..cfg.ctx + cfg.ctx / 2).map(|i| ((i * 11 + 5) % 251) as i32).collect();

        let mut cache = KvCache::with_codec(cfg, Some(codec.clone()));
        let slid_logits = hf.prefill(&stream, &mut cache).unwrap();
        assert!(cache.evictions() >= 1, "{bits} bits: stream never crossed the slide boundary");
        assert!(codec.frozen());
        let books = codec.codebook_bits();

        // fresh quantized prefill of the surviving window, same frozen codec
        let window = cache.tokens().to_vec();
        let mut fresh = KvCache::with_codec(cfg, Some(codec.clone()));
        let fresh_logits = hf.prefill(&window, &mut fresh).unwrap();
        assert_eq!(cache.tokens(), fresh.tokens(), "{bits} bits: window mismatch");
        assert_eq!(slid_logits, fresh_logits, "{bits} bits: logits after slide");
        for layer in 0..cfg.n_layer {
            let (k1, v1) = cache.layer(layer);
            let (k2, v2) = fresh.layer(layer);
            for pos in 0..cache.len() {
                assert_eq!(
                    cache.k_codes(layer, pos),
                    fresh.k_codes(layer, pos),
                    "{bits} bits: K codes {layer}/{pos} — rebuilt rows used a different codebook"
                );
                assert_eq!(
                    cache.v_codes(layer, pos),
                    fresh.v_codes(layer, pos),
                    "{bits} bits: V codes {layer}/{pos}"
                );
                assert_eq!(k1.row(pos), k2.row(pos), "{bits} bits: K tile {layer}/{pos}");
                assert_eq!(v1.row(pos), v2.row(pos), "{bits} bits: V tile {layer}/{pos}");
            }
        }
        assert_eq!(
            codec.codebook_bits(),
            books,
            "{bits} bits: eviction rebuilt the codebook instead of reusing the frozen one"
        );

        // the paged layout rides the same slide schedule and codec
        let pool = KvPool::with_codec(cfg, 4, Some(codec.clone())).unwrap();
        let mut paged = PagedKvCache::new(cfg, &pool);
        let paged_logits = hf.prefill(&stream, &mut paged).unwrap();
        assert_eq!(paged_logits, slid_logits, "{bits} bits: paged logits after slide");
        assert_eq!(paged.tokens(), cache.tokens(), "{bits} bits: paged window");
        assert!(paged.evictions() >= 1);
        for layer in 0..cfg.n_layer {
            let (kd, vd) = cache.layer(layer);
            for pos in 0..cache.len() {
                assert_eq!(paged.k_row(layer, pos), kd.row(pos), "{bits} bits: paged K");
                assert_eq!(paged.v_row(layer, pos), vd.row(pos), "{bits} bits: paged V");
            }
        }
        assert_eq!(codec.codebook_bits(), books, "{bits} bits: paged slide rebuilt the codebook");
    }
}
