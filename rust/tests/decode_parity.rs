//! KV-cached incremental decode vs the windowed re-forward oracle.
//!
//! The contract (DESIGN.md §9): `HostForward::decode_step` logits equal the
//! last row of a fresh full forward over `cache.tokens()` within 1e-5, at
//! every prompt length — including past capacity, where the cache slides by
//! its eviction stride and rebuilds. Plus: cache state is a pure function of
//! the token stream (a reused-then-reset cache equals a fresh one), and the
//! stateful eval paths (incremental ppl, session greedy decode) match their
//! block-forward counterparts.

use pcdvq::eval::{evaluate_ppl, greedy_decode, ForwardPass};
use pcdvq::model::{GptModel, HostForward, KvCache, QuantizedGpt};
use pcdvq::proptest::{for_cases, synthetic_tinygpt, tiny_pcdvq};
use pcdvq::quant::pcdvq::Pcdvq;

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the decode-parity testbed.
fn synthetic_model(name: &str) -> GptModel {
    synthetic_tinygpt("pcdvq_decode_parity", name, 23)
}

/// A small PCDVQ (a=8) built directly — the codes-resident parity case.
fn small_pcdvq() -> Pcdvq {
    tiny_pcdvq()
}

fn tokens_of(n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 37 + 11) % 251) as i32).collect()
}

/// Assert `logits` (from the cached path) equals the oracle: last-row logits
/// of a full re-forward over the cache's current window.
fn assert_oracle_parity(hf: &HostForward, cache: &KvCache, logits: &[f32], what: &str) {
    let t = cache.len();
    let v = hf.config.vocab;
    let oracle = hf.forward(cache.tokens(), 1, t).unwrap();
    let last = &oracle[(t - 1) * v..t * v];
    assert_eq!(logits.len(), v, "{what}: logit width");
    for (j, (a, b)) in logits.iter().zip(last).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5,
            "{what}: logit {j} cached {a} vs oracle {b} (window {t})"
        );
    }
}

/// The headline parity matrix: prompt lengths 1, ctx-1, ctx and ctx+7 (the
/// eviction path), each checked at the prefill boundary and across five
/// greedy continuation steps, on both the dense and the codes-resident host
/// backend.
#[test]
fn cached_decode_matches_reforward_oracle() {
    let model = synthetic_model("oracle");
    let ctx = model.config.ctx;
    let q = QuantizedGpt::quantize(&model, &small_pcdvq());
    let backends = [
        ("dense", HostForward::from_dense(model.clone()).unwrap()),
        ("codes", HostForward::from_quantized(q).unwrap()),
    ];
    for (label, hf) in &backends {
        for plen in [1, ctx - 1, ctx, ctx + 7] {
            let mut cache = KvCache::new(&model.config);
            let prompt = tokens_of(plen);
            let mut logits = hf.prefill(&prompt, &mut cache).unwrap();
            if plen <= ctx {
                assert_eq!(cache.tokens(), &prompt[..], "window below capacity is exact");
            } else {
                assert!(cache.evictions() >= 1, "{label}: prompt past ctx must slide");
                assert!(cache.len() < ctx);
            }
            assert_oracle_parity(hf, &cache, &logits, &format!("{label} prefill({plen})"));
            for step in 0..5 {
                let next = pcdvq::tensor::argmax(&logits) as i32;
                logits = hf.decode_step(next, &mut cache).unwrap();
                assert_oracle_parity(
                    hf,
                    &cache,
                    &logits,
                    &format!("{label} prefill({plen}) step {step}"),
                );
            }
        }
    }
}

/// The slide is deterministic: feeding ctx+7 tokens through a stride-16
/// cache leaves exactly the suffix the eviction arithmetic predicts.
#[test]
fn eviction_keeps_the_expected_suffix() {
    let model = synthetic_model("evict");
    let hf = HostForward::from_dense(model.clone()).unwrap();
    let ctx = model.config.ctx;
    let mut cache = KvCache::new(&model.config);
    let stride = cache.evict_stride();
    assert_eq!(stride, ctx / 4);
    let input = tokens_of(ctx + 7);
    hf.prefill(&input, &mut cache).unwrap();
    // one slide at token ctx: window = input[stride..]
    assert_eq!(cache.evictions(), 1);
    assert_eq!(cache.len(), ctx - stride + 7);
    assert_eq!(cache.tokens(), &input[stride..]);
    // rebuild re-feeds the kept window, so total_fed counts it twice
    assert_eq!(cache.total_fed() as usize, (ctx + 7) + (ctx - stride));
}

/// Property: cache state is a pure function of the token stream. A cache
/// that served a previous request and was reset matches a fresh cache fed
/// the same N tokens — bit-exact across tokens, K and V of every layer, and
/// the final logits.
#[test]
fn prop_reset_cache_equals_fresh_cache() {
    let model = synthetic_model("prop");
    let hf = HostForward::from_dense(model.clone()).unwrap();
    let ctx = model.config.ctx;
    for_cases(6, 0xCAFE, |g| {
        // previous "request": arbitrary traffic, then an explicit reset
        let mut reused = KvCache::new(&model.config);
        let garbage: Vec<i32> =
            (0..g.usize_in(1, ctx + 20)).map(|_| g.rng.below(251) as i32).collect();
        hf.prefill(&garbage, &mut reused).unwrap();
        reused.reset();

        let n = g.usize_in(1, ctx + 20);
        let stream: Vec<i32> = (0..n).map(|_| g.rng.below(251) as i32).collect();
        // reused cache: token-by-token decode_step
        let mut last_a = Vec::new();
        for &t in &stream {
            last_a = hf.decode_step(t, &mut reused).unwrap();
        }
        // fresh cache: one prefill
        let mut fresh = KvCache::new(&model.config);
        let last_b = hf.prefill(&stream, &mut fresh).unwrap();

        assert_eq!(reused.len(), fresh.len(), "case {}", g.case_seed);
        assert_eq!(reused.tokens(), fresh.tokens(), "case {}", g.case_seed);
        for layer in 0..model.config.n_layer {
            let (ka, va) = reused.layer(layer);
            let (kb, vb) = fresh.layer(layer);
            for i in 0..reused.len() {
                assert_eq!(ka.row(i), kb.row(i), "K layer {layer} row {i}");
                assert_eq!(va.row(i), vb.row(i), "V layer {layer} row {i}");
            }
        }
        assert_eq!(last_a, last_b, "case {}", g.case_seed);
    });
}

/// Block-only view of a host backend: hides the decode session so the
/// fallback paths (batched ppl, windowed greedy decode) can be pinned
/// against the stateful ones.
struct BlockOnly<'a>(&'a HostForward);

impl ForwardPass for BlockOnly<'_> {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.0.forward(&tokens, b, t)
    }
}

#[test]
fn incremental_ppl_matches_block_ppl() {
    let model = synthetic_model("ppl");
    let hf = HostForward::from_dense(model.clone()).unwrap();
    let ctx = model.config.ctx;
    let tokens: Vec<u32> = (0..3 * ctx + 1).map(|i| ((i * 31) % 251) as u32).collect();
    for temperature in [1.0f32, 1.2] {
        let inc = evaluate_ppl(&hf, &model.config, &tokens, 1, 3, temperature).unwrap();
        let blk =
            evaluate_ppl(&BlockOnly(&hf), &model.config, &tokens, 1, 3, temperature).unwrap();
        assert_eq!(inc.n_tokens, blk.n_tokens);
        assert!(
            (inc.nll - blk.nll).abs() < 1e-6,
            "t={temperature}: incremental nll {} vs block {}",
            inc.nll,
            blk.nll
        );
    }
}

#[test]
fn session_greedy_decode_matches_windowed() {
    let model = synthetic_model("greedy");
    let q = QuantizedGpt::quantize(&model, &small_pcdvq());
    let hf = HostForward::from_quantized(q).unwrap();
    let prompt: Vec<u8> = b"polar coordinate".to_vec();
    let cached = greedy_decode(&hf, &model.config, &prompt, 12).unwrap();
    let windowed = greedy_decode(&BlockOnly(&hf), &model.config, &prompt, 12).unwrap();
    assert_eq!(cached.len(), 12);
    assert_eq!(cached, windowed, "session and windowed greedy decode diverged");
}
