//! Serving demo: the batched generation service on PCDVQ codes.
//!
//! ```text
//! cargo run --release --example serve_quantized [model] [n_requests]
//! ```
//!
//! Spawns client threads that submit prompts at random offsets of the eval
//! corpus, runs the coordinator's batcher + server on the `fwd_q` artifact
//! (weights live as 2-bit codes; dequant happens inside the executable), and
//! prints the §4.4-style metrics: tokens/s, batch occupancy, latency
//! percentiles, resident weight bytes.
//!
//! When the PJRT backend is unavailable the demo falls back to the host
//! **codes-resident** server: the same packed codes + shared codebooks are
//! served straight through `matmul_from_codes`, with no XLA artifact and no
//! dense weights at any point.

use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::Result;
use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::config::{build_pcdvq_with, Paths};
use pcdvq::coordinator::{Batcher, BatcherConfig, GenRequest, Server, ServingWeights};
use pcdvq::model::QuantizedGpt;
use pcdvq::rng::Rng;
use pcdvq::runtime::Engine;

fn main() -> Result<()> {
    let paths = Paths::detect();
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "gpt-m".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let model = paths.load_model(&model_name)?;

    // quantize to codes (this is what would ship to the edge device)
    let pcdvq =
        build_pcdvq_with(&paths, DirectionMethod::GreedyE8, MagnitudeMethod::LloydMax, 14, 2, 7)?;
    let t = Instant::now();
    let q = QuantizedGpt::quantize(&model, &pcdvq);
    println!(
        "quantized {model_name} to PCDVQ codes in {:.1}s: {} KiB payload (+{} KiB shared \
         codebooks) vs {} KiB fp32 ({:.1}x)",
        t.elapsed().as_secs_f64(),
        q.payload_bits() / 8 / 1024,
        q.codebook_bits() / 8 / 1024,
        q.dense_bits() / 8 / 1024,
        q.dense_bits() as f64 / q.payload_bits() as f64
    );

    let mut server = match Engine::new() {
        Ok(engine) => Server::new(
            &engine,
            &paths.artifacts,
            ServingWeights::Quantized(Box::new(q), (*pcdvq.dir).clone(), (*pcdvq.mag).clone()),
        )?,
        Err(e) => {
            println!("PJRT unavailable ({e:#}); serving codes-resident on the host");
            Server::builder(ServingWeights::CodesResident(Box::new(q))).build()?
        }
    };

    // client side: one burst of requests through the batcher
    let eval_tokens = paths.eval_tokens()?;
    let (tx, rx) = channel::<GenRequest>();
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rng = Rng::new(7);
    let mut responses = Vec::new();
    for i in 0..n_requests {
        let s = rng.below(eval_tokens.len() - 80);
        let prompt: Vec<u8> = eval_tokens[s..s + 56].iter().map(|&t| t as u8).collect();
        let (rtx, rrx) = channel();
        let req = GenRequest::builder(prompt)
            .max_new(24)
            .temperature(if i % 2 == 0 { 0.0 } else { 0.7 })
            .build(rtx);
        tx.send(req).unwrap();
        responses.push(rrx);
    }
    drop(tx);
    if server.is_codes_resident() {
        // host backend: continuous batching + block prefill
        server.serve_continuous(&mut batcher)?;
    } else {
        server.serve(&mut batcher)?;
    }

    println!("\nserver metrics: {}", server.metrics.summary());
    for (i, rrx) in responses.iter().enumerate().take(3) {
        if let Ok(resp) = rrx.recv() {
            println!(
                "sample {}: {:?} ({} steps, {:.0} ms)",
                i,
                String::from_utf8_lossy(&resp.generated)
                    .chars()
                    .take(40)
                    .collect::<String>(),
                resp.steps,
                resp.latency.as_millis()
            );
        }
    }
    Ok(())
}
