//! Sensitivity sweep: how the (a, b) bit split moves accuracy — the design
//! question behind the paper's "allocate more bits to direction" principle.
//!
//! ```text
//! cargo run --release --example sensitivity_sweep [model]
//! ```
//!
//! Holds the total index budget fixed at 16 bits per 8-vector (2.0 bpw) and
//! sweeps the direction/magnitude split, measuring reconstruction error and
//! model quality for each. The paper's choice (a=14, b=2) should sit at or
//! near the optimum — a finer-grained version of Figure 1(a)'s argument.

use anyhow::Result;
use pcdvq::config::{build_pcdvq_with, Paths};
use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::coordinator::quantize_model_parallel;
use pcdvq::eval::{evaluate_ppl, weight_inputs};
use pcdvq::quant::error::decompose_weights;
use pcdvq::runtime::Engine;

fn main() -> Result<()> {
    let paths = Paths::detect();
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "gpt-m".into());
    let model = paths.load_model(&model_name)?;
    let engine = Engine::new()?;
    let eval_tokens = paths.eval_tokens()?;

    println!("total budget fixed at a+b = 16 bits / 8-vector (2.0 bpw), {model_name}\n");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>9}",
        "(a, b)", "dir MSE", "mag MSE", "total MSE", "ppl"
    );
    for (a, b) in [(10u32, 6u32), (12, 4), (13, 3), (14, 2), (15, 1)] {
        let q = build_pcdvq_with(
            &paths,
            DirectionMethod::GreedyE8,
            MagnitudeMethod::LloydMax,
            a,
            b,
            7,
        )?;
        let (qm, _) = quantize_model_parallel(&model, &q, 1);
        // error decomposition over all layers
        let (mut dir, mut mag, mut tot, mut n) = (0.0f64, 0.0f64, 0.0f64, 0usize);
        for name in model.config.quantizable_names() {
            let d = decompose_weights(&model.tensors[&name], &qm.tensors[&name], 8);
            dir += d.direction_mse * d.count as f64;
            mag += d.magnitude_mse * d.count as f64;
            tot += d.total_mse * d.count as f64;
            n += d.count;
        }
        let exe = engine.load(paths.artifacts.join(format!("fwd_fp_{model_name}_b8")))?;
        let fixed = weight_inputs(&qm, &exe.manifest)?;
        let bound = exe.bind(&fixed, 1)?;
        let ppl = evaluate_ppl(&bound, &model.config, &eval_tokens, 8, 32, 1.0)?;
        println!(
            "({a:>2},{b:>2})     {:>10.5} {:>10.5} {:>10.5} {:>9.3}",
            dir / n as f64,
            mag / n as f64,
            tot / n as f64,
            ppl.ppl
        );
    }
    println!("\nexpectation: total MSE and ppl minimized near the paper's (14, 2);");
    println!("starving the direction codebook (small a) hurts most.");
    Ok(())
}
