//! End-to-end driver (the repository's headline validation run).
//!
//! ```text
//! make artifacts && cargo run --release --example quantize_llm
//! ```
//!
//! Loads a *real trained* tinygpt from the artifacts (trained at build time
//! on the byte corpus), quantizes it with PCDVQ and the strongest baseline
//! through the layer-parallel scheduler, and evaluates perplexity + the five
//! zero-shot proxy tasks through the AOT forward executable — proving all
//! three layers compose: Rust coordinator → PJRT runtime → JAX/Pallas
//! graphs. The run is recorded in EXPERIMENTS.md.

use anyhow::Result;
use pcdvq::config::{MethodSpec, Paths};
use pcdvq::coordinator::quantize_model_parallel;
use pcdvq::eval::{evaluate_ppl, evaluate_tasks, weight_inputs, TASK_NAMES};
use pcdvq::runtime::Engine;

fn main() -> Result<()> {
    let paths = Paths::detect();
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "gpt-m".into());
    let model = paths.load_model(&model_name)?;
    println!(
        "loaded {model_name}: {:.2}M params ({:.2}M quantizable), d={} L={} ctx={}",
        model.param_count() as f64 / 1e6,
        model.config.quantizable_params() as f64 / 1e6,
        model.config.d_model,
        model.config.n_layer,
        model.config.ctx
    );
    let engine = Engine::new()?;
    println!("PJRT platform: {}", engine.platform());
    let eval_tokens = paths.eval_tokens()?;
    println!("eval corpus: {} bytes held out\n", eval_tokens.len());

    let mut rows = Vec::new();
    for spec_name in ["fp16", "rtn2", "quip16", "pcdvq2", "pcdvq2.125"] {
        let spec = MethodSpec::parse(spec_name)?;
        let (eval_model, bpw) = if spec == MethodSpec::Fp16 {
            (model.clone(), 16.0)
        } else {
            let quantizer = spec.build(&paths, &model, 7)?;
            let t = std::time::Instant::now();
            let (qm, stats) = quantize_model_parallel(&model, quantizer.as_ref(), 1);
            println!(
                "[quantize] {} -> {:.3} bpw in {:.1}s ({} layers)",
                spec.label(),
                stats.achieved_bpw,
                t.elapsed().as_secs_f64(),
                stats.layers.len()
            );
            (qm, stats.achieved_bpw)
        };
        let exe = engine.load(paths.artifacts.join(format!("fwd_fp_{model_name}_b8")))?;
        let fixed = weight_inputs(&eval_model, &exe.manifest)?;
        let bound = exe.bind(&fixed, 1)?;
        let ppl = evaluate_ppl(&bound, &model.config, &eval_tokens, 8, 48, 1.0)?;
        let tasks = evaluate_tasks(&bound, &model.config, &eval_tokens, 8, 64, 99)?;
        println!(
            "[eval] {:<24} ppl {:>7.3}  bits/byte {:>6.4}  QA avg {:>5.1}%",
            spec.label(),
            ppl.ppl,
            ppl.bits_per_byte,
            tasks.avg * 100.0
        );
        for (name, acc) in TASK_NAMES.iter().zip(&tasks.accuracy) {
            println!("         {name:<10} {:.1}%", acc * 100.0);
        }
        rows.push((spec.label(), bpw, ppl.ppl, tasks.avg * 100.0));
    }

    println!("\n=== summary ({model_name}) ===");
    println!("{:<26} {:>7} {:>9} {:>8}", "method", "bpw", "ppl", "QA avg");
    for (label, bpw, ppl, qa) in &rows {
        println!("{label:<26} {bpw:>7.3} {ppl:>9.3} {qa:>7.1}%");
    }
    // sanity: the paper's ordering must hold
    let ppl_of = |name: &str| {
        rows.iter()
            .find(|(l, ..)| l.contains(name))
            .map(|&(_, _, p, _)| p)
            .unwrap()
    };
    assert!(
        ppl_of("PCDVQ a=14") < ppl_of("RTN"),
        "PCDVQ must beat 2-bit SQ"
    );
    assert!(
        ppl_of("PCDVQ a=14") < ppl_of("QuIP"),
        "PCDVQ must beat the coupled-VQ baseline"
    );
    println!("\nordering check passed: PCDVQ < QuIP#-like < RTN at 2 bits. ✔");
    Ok(())
}
