//! Quickstart: quantize one weight matrix with PCDVQ, end to end.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full pipeline on a synthetic Gaussian weight: DACC codebook
//! construction (greedy-E8 directions + Lloyd-Max magnitudes) → RHT
//! regularization → polar decoupling → assignment → packing → dequantization,
//! printing the error decomposition and the storage accounting at both paper
//! operating points (2.0 and 2.125 bpw).

use std::sync::Arc;

use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook};
use pcdvq::quant::error::decompose_weights;
use pcdvq::quant::pcdvq::{Pcdvq, PcdvqConfig};
use pcdvq::quant::Quantizer;
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    // A synthetic "linear layer": 512x512, Gaussian with a few outliers —
    // the RHT step exists exactly to tame those.
    let mut rng = Rng::new(42);
    let mut data = rng.normal_vec(512 * 512);
    for i in (0..data.len()).step_by(10_007) {
        data[i] *= 25.0;
    }
    let w = Matrix::from_vec(data, 512, 512);
    println!("weight: 512x512, fro norm {:.1}", w.fro_norm());

    for (a, b) in [(14u32, 2u32), (15, 2)] {
        let bpw = (a + b) as f64 / 8.0;
        println!("\n== PCDVQ at {} bpw (a={a}, b={b}, k=8) ==", bpw);

        // 1. DACC codebooks (offline, cached in real runs — built here fresh)
        let t = std::time::Instant::now();
        let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, a, 8, 0));
        let mag = Arc::new(MagnitudeCodebook::paper_default(b, 8));
        println!(
            "codebooks: {} directions (greedy E8) + {:?} magnitudes (Lloyd-Max) in {:.1}s",
            dir.len(),
            mag.levels,
            t.elapsed().as_secs_f64()
        );

        // 2. quantize (RHT → decouple → assign → pack)
        let q = Pcdvq::new(PcdvqConfig { dir_bits: a, mag_bits: b, k: 8, seed: 7 }, dir, mag);
        let t = std::time::Instant::now();
        let qw = q.quantize_full(&w);
        println!(
            "quantized {} vectors in {:.2}s -> {} KiB payload ({:.4} bpw incl. metadata)",
            qw.n_vectors(),
            t.elapsed().as_secs_f64(),
            qw.payload_bits() / 8 / 1024,
            qw.payload_bits() as f64 / w.len() as f64
        );

        // 3. dequantize + measure
        let deq = q.dequantize_full(&qw);
        let d = decompose_weights(&w, &deq, 8);
        println!(
            "reconstruction: total MSE {:.5} | direction {:.5} | magnitude {:.5} (per 8-vector)",
            d.total_mse, d.direction_mse, d.magnitude_mse
        );
        println!(
            "relative fro error {:.4}",
            (w.mse(&deq) * w.len() as f64).sqrt() / w.fro_norm() as f64
        );

        // 4. the Quantizer trait view (what the scheduler drives)
        let qws = q.quantize(&w);
        println!("trait bpw accounting: {:.3} nominal", q.bits_per_weight());
        assert_eq!(qws.dequantize().rows(), 512);
    }
    println!("\nquickstart OK");
    Ok(())
}
